//! ATC diffusion over the dual problem — the paper's core algorithm
//! (Eqs. 31/35, specialized in Algs. 2–4).
//!
//! Per iteration, every agent `k` runs a local **adapt** step
//!
//! ```text
//! ψ_k = ν_k − μ·∇J_k(ν_k; x)
//!     = ν_k − μ·(c_f/N · ν_k − θ_k/|N_I| · x) − (μ/δ)·W_k thr_γ(W_kᵀ ν_k)
//! ```
//!
//! followed by the neighborhood **combine** `ν_k = Σ_ℓ a_{ℓk} ψ_ℓ`
//! (optionally projected onto `V_f` for the Huber task, Eq. 35b). The
//! engine stores the stacked iterates as `V ∈ R^{N×M}` and dispatches the
//! combine `V ← AᵀΨ` over three paths, selected when the combination
//! matrix is installed (`new` / `set_combination`):
//!
//! * **uniform** — `A = (1/N)·11ᵀ`: combine collapses to a row average,
//!   `O(N·M)`;
//! * **sparse** — `Aᵀ` stored in CSR when its density is at most
//!   [`SPARSE_DENSITY_MAX`]: combine is an spmm, `O(|E|·M)` — the scaling
//!   regime the paper targets (hundreds of agents, small neighborhoods);
//! * **dense** — the blocked gemm fallback, `O(N²·M)`.
//!
//! Both the embarrassingly-parallel adapt loop and the combine row ranges
//! run on a scoped worker pool when `DiffusionParams::threads > 1`. Work is
//! split by static row partition ([`crate::net::chunk_range`]), so every
//! row is produced by the same arithmetic regardless of thread count — the
//! `ν` trajectory is bit-identical for `threads = 1` and `threads = T`.
//!
//! ## Batched inference
//!
//! [`DiffusionEngine::run_batch`] stacks `B` samples as `V ∈ R^{N×(B·M)}`:
//! row `k` holds agent `k`'s `B` dual iterates back to back. Samples never
//! interact — adapt is per-(agent, sample) and combine multiplies the same
//! `Aᵀ` against the wider `Ψ` — so one CSR traversal / gemm / row-mean and
//! one worker-pool sweep amortize across the minibatch while each sample's
//! trajectory stays **bit-identical** to a sequential [`DiffusionEngine::run`]
//! per sample (each output element accumulates in the same order; see
//! `tests/combine_parity.rs`). The batched adapt additionally amortizes the
//! strided dictionary-column walk across samples
//! ([`DistributedDictionary::block_correlations_batched`]).
//!
//! Buffers (including per-worker threshold scratch) are grow-only and
//! reused: the per-iteration hot loop performs no heap allocation, and a
//! batch-size change re-shapes the active region of the already-allocated
//! buffers (sized to the largest `B` seen) instead of re-allocating — so a
//! stream that alternates full and final-partial batches pays only a
//! re-zero per swap, never an allocation (see EXPERIMENTS.md §Perf /
//! §Serving). Changing `B` is still a *cold start* for the iterates.
//!
//! Threaded runs either spawn scoped workers per call
//! ([`crate::net::WorkerPool`]) or, when a long-lived
//! [`crate::net::PersistentPool`] is installed via
//! [`DiffusionEngine::set_pool`], dispatch to persistent threads — the
//! serving pipeline installs one such pool per in-flight inference slot
//! (a pool runs one SPMD region at a time; see `net/pool.rs`).

use crate::error::{DdlError, Result};
use crate::math::{blas, CsrMat, Mat};
use crate::model::{DistributedDictionary, TaskSpec};
use crate::net::pool::{chunk_range, PersistentPool, SharedRows, WorkerPool};
use crate::ops::project::clip_linf;
use std::sync::{Arc, Barrier};

/// Densest combination matrix the engine will store as CSR: below this fill
/// fraction spmm beats the blocked gemm comfortably; above it, gemm's
/// locality wins.
pub const SPARSE_DENSITY_MAX: f32 = 0.25;

/// Diffusion hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct DiffusionParams {
    /// Step size μ.
    pub mu: f32,
    /// Iteration count.
    pub iters: usize,
    /// Worker threads for the adapt/combine loops (1 = serial). Results
    /// are bit-identical for every value.
    pub threads: usize,
}

impl DiffusionParams {
    /// Serial parameters (the common case).
    pub fn new(mu: f32, iters: usize) -> Self {
        DiffusionParams { mu, iters, threads: 1 }
    }

    /// Builder-style thread override.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }
}

/// Combine-path dispatch, chosen when the combination matrix is installed.
enum Combine {
    /// `A = (1/N)·11ᵀ`: combine is the column mean broadcast to all rows.
    Uniform,
    /// CSR of `Aᵀ` — combine is an spmm over neighborhood edges only.
    Sparse(CsrMat),
    /// Dense `Aᵀ` — combine is one row-major gemm.
    Dense(Mat),
}

impl Combine {
    fn build(a: &Mat) -> Combine {
        if is_uniform(a) {
            return Combine::Uniform;
        }
        let n = a.rows();
        let nnz = a.as_slice().iter().filter(|v| **v != 0.0).count();
        if (nnz as f32) <= SPARSE_DENSITY_MAX * (n * n) as f32 {
            Combine::Sparse(CsrMat::from_dense_transposed(a, 0.0))
        } else {
            Combine::Dense(a.transpose())
        }
    }

    fn path(&self) -> &'static str {
        match self {
            Combine::Uniform => "uniform",
            Combine::Sparse(_) => "sparse",
            Combine::Dense(_) => "dense",
        }
    }
}

/// Read-only view of a stacked dual iterate buffer `V ∈ R^{N×(B·M)}`:
/// row `k` holds agent `k`'s `B` per-sample iterates back to back.
///
/// This is the engine's readout surface factored out of the engine itself,
/// so the same per-sample arithmetic (primal recovery, consensus,
/// disagreement) runs identically on the live engine state
/// ([`DiffusionEngine::nu_view`]) and on a `V` clone shipped to another
/// pipeline stage ([`NuView::to_owned_data`] → [`NuView::new`]) — the
/// bitwise-parity backbone of the pipelined serving path.
#[derive(Clone, Copy, Debug)]
pub struct NuView<'a> {
    data: &'a [f32],
    n: usize,
    m: usize,
    b: usize,
}

impl<'a> NuView<'a> {
    /// Wrap a flat `N × (B·M)` buffer.
    pub fn new(data: &'a [f32], n: usize, m: usize, b: usize) -> Self {
        debug_assert_eq!(data.len(), n * b * m);
        NuView { data, n, m, b }
    }

    /// Number of agents `N`.
    pub fn agents(&self) -> usize {
        self.n
    }

    /// Data dimension `M`.
    pub fn dim(&self) -> usize {
        self.m
    }

    /// Batch size `B`.
    pub fn batch(&self) -> usize {
        self.b
    }

    /// Agent `k`'s dual estimate for sample `s`.
    pub fn nu(&self, k: usize, s: usize) -> &'a [f32] {
        debug_assert!(k < self.n && s < self.b);
        let data: &'a [f32] = self.data;
        &data[k * self.b * self.m + s * self.m..][..self.m]
    }

    /// Copy the underlying buffer (to ship `V` to another pipeline stage).
    pub fn to_owned_data(&self) -> Vec<f32> {
        self.data.to_vec()
    }

    /// Network-average dual estimate for sample `s`, written into `out`
    /// (length `M`).
    pub fn consensus_into(&self, s: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.m);
        out.fill(0.0);
        for k in 0..self.n {
            crate::math::vector::axpy(1.0, self.nu(k, s), out);
        }
        crate::math::vector::scale(1.0 / self.n as f32, out);
    }

    /// Maximum pairwise disagreement `max_k ‖ν_k − ν̄‖` for sample `s`;
    /// `mean` is an `M`-length scratch buffer (overwritten with the
    /// consensus estimate).
    pub fn disagreement_into(&self, s: usize, mean: &mut [f32]) -> f32 {
        self.consensus_into(s, mean);
        (0..self.n)
            .map(|k| crate::math::vector::dist_sq(self.nu(k, s), mean).sqrt())
            .fold(0.0f32, f32::max)
    }
}

/// Primal recovery (Eq. 37 / Table II) from a dual view: `y_q =
/// thr_γ(w_qᵀ ν_k)/δ` for each agent's own atoms, using each agent's
/// **local** dual iterate for sample `s`. `y` and `scratch` are `K`-length
/// buffers. Shared verbatim by [`DiffusionEngine::recover_y_sample_into`]
/// and the pipelined updater stage, so both produce bit-identical
/// coefficients.
pub fn recover_y_into(
    dict: &DistributedDictionary,
    task: &TaskSpec,
    nu: &NuView<'_>,
    s: usize,
    y: &mut [f32],
    scratch: &mut [f32],
) {
    debug_assert_eq!(y.len(), dict.k());
    debug_assert_eq!(scratch.len(), dict.k());
    let inv_delta = 1.0 / task.delta();
    for k in 0..nu.agents() {
        dict.block_correlations(k, nu.nu(k, s), scratch);
        let (start, len) = dict.block(k);
        for q in start..start + len {
            y[q] = task.threshold(scratch[q]) * inv_delta;
        }
    }
}

/// Reusable diffusion inference engine for a fixed network size.
pub struct DiffusionEngine {
    /// Stacked dual iterates `V` (`N × (B·M)`): row `k` holds agent `k`'s
    /// `B` per-sample iterates back to back (`B = 1` for [`Self::run`]).
    /// Backed by a flat grow-only buffer sized for the *largest* batch seen
    /// (`batch_cap`), of which the leading `N·B·M` elements are active at
    /// row stride `B·M` — so alternating full and final-partial batches
    /// re-shape without re-allocating (see [`Self::reserve_batch`]).
    v: Vec<f32>,
    /// Adapt outputs `Ψ`, same layout and capacity policy as `v`.
    psi: Vec<f32>,
    /// Combine dispatch (uniform / CSR spmm / dense gemm).
    combine: Combine,
    /// Scratch: per-atom per-sample thresholded correlations (`K·B`,
    /// layout `[q·B + s]`), serial path. Grow-only; sliced to the active
    /// `K·B` prefix per run.
    thr: Vec<f32>,
    /// Per-worker threshold scratch for the threaded path; grow-only and
    /// reused across `run` calls.
    worker_thr: Vec<Vec<f32>>,
    /// Informed-agent mask θ (`N`), entries 1/|N_I| or 0 (Eq. 29).
    theta: Vec<f32>,
    /// Optional long-lived worker pool; when installed, threaded runs
    /// dispatch to it instead of spawning scoped threads per call
    /// (identical results — see `net/pool.rs`).
    pool: Option<Arc<PersistentPool>>,
    n: usize,
    m: usize,
    /// Current batch size `B` (`V`/`Ψ` active regions hold `batch · m`
    /// columns per row).
    batch: usize,
    /// Largest batch size seen — the allocation high-water mark of `v` /
    /// `psi`.
    batch_cap: usize,
}

impl DiffusionEngine {
    /// Create an engine for an `n`-agent network over data dimension `m`.
    ///
    /// `informed`: indices of the agents in `N_I` that observe the data
    /// sample (paper Fig. 1); pass `None` for "all agents informed".
    pub fn new(a: &Mat, m: usize, informed: Option<&[usize]>) -> Result<Self> {
        let n = a.rows();
        if a.cols() != n {
            return Err(DdlError::Shape("combination matrix must be square".into()));
        }
        Ok(DiffusionEngine {
            v: vec![0.0; n * m],
            psi: vec![0.0; n * m],
            combine: Combine::build(a),
            thr: Vec::new(),
            worker_thr: Vec::new(),
            theta: build_theta(n, informed)?,
            pool: None,
            n,
            m,
            batch: 1,
            batch_cap: 1,
        })
    }

    /// Create an engine directly from a CSR combination matrix (`Aᵀ` rows,
    /// as produced by [`crate::graph::metropolis_csr`]) — the dense `N×N`
    /// form is never materialized.
    pub fn new_csr(at: CsrMat, m: usize, informed: Option<&[usize]>) -> Result<Self> {
        let n = at.rows();
        if at.cols() != n {
            return Err(DdlError::Shape("combination matrix must be square".into()));
        }
        Ok(DiffusionEngine {
            v: vec![0.0; n * m],
            psi: vec![0.0; n * m],
            combine: Combine::Sparse(at),
            thr: Vec::new(),
            worker_thr: Vec::new(),
            theta: build_theta(n, informed)?,
            pool: None,
            n,
            m,
            batch: 1,
            batch_cap: 1,
        })
    }

    /// Replace the combination matrix (topology change between time-steps).
    pub fn set_combination(&mut self, a: &Mat) -> Result<()> {
        if a.rows() != self.n || a.cols() != self.n {
            return Err(DdlError::Shape("combination matrix shape mismatch".into()));
        }
        self.combine = Combine::build(a);
        Ok(())
    }

    /// Replace the combination matrix with a CSR `Aᵀ` (sparse path forced).
    pub fn set_combination_csr(&mut self, at: CsrMat) -> Result<()> {
        if at.rows() != self.n || at.cols() != self.n {
            return Err(DdlError::Shape("combination matrix shape mismatch".into()));
        }
        self.combine = Combine::Sparse(at);
        Ok(())
    }

    /// Install a combination matrix on the dense-gemm path regardless of
    /// its sparsity (benchmark / equivalence-test comparator).
    pub fn set_combination_dense(&mut self, a: &Mat) -> Result<()> {
        if a.rows() != self.n || a.cols() != self.n {
            return Err(DdlError::Shape("combination matrix shape mismatch".into()));
        }
        self.combine = Combine::Dense(a.transpose());
        Ok(())
    }

    /// Install a long-lived worker pool: threaded runs dispatch their SPMD
    /// regions to it instead of spawning scoped threads per call. The
    /// effective thread count is `min(params.threads, pool.threads(), N)`;
    /// results are bit-identical to the scoped path at the same count. The
    /// `Arc` handle is cheap to clone and shareable across pipeline stages.
    pub fn set_pool(&mut self, pool: Arc<PersistentPool>) {
        self.pool = Some(pool);
    }

    /// Remove the installed worker pool (back to scoped spawning).
    pub fn clear_pool(&mut self) {
        self.pool = None;
    }

    /// Pre-size the threshold scratch for a dictionary with `atoms` total
    /// atoms, so even the first `run` call allocates nothing. `run` calls
    /// this itself (a no-op once sized); streaming callers may invoke it
    /// eagerly at setup time. Sizing is for the engine's *current* batch
    /// size — call [`Self::reserve_batch`] first when pre-sizing for
    /// batched runs. Grow-only: shrinking the batch slices the existing
    /// buffer instead of re-allocating.
    pub fn reserve_atoms(&mut self, atoms: usize) {
        let want = atoms * self.batch;
        if self.thr.len() < want {
            self.thr.resize(want, 0.0);
        }
    }

    /// Re-shape `V`/`Ψ` for a batch of `b` samples (`b·M` active columns).
    /// A no-op when the batch size is unchanged; otherwise the active
    /// region is re-zeroed (a cold start — per-sample state cannot survive
    /// a batch-shape change). The backing buffers are sized to the largest
    /// batch ever seen and only *grow*: streaming callers that alternate
    /// between a full and a partial final batch re-shape for free instead
    /// of re-allocating `2·N·B·M` floats per size change.
    pub fn reserve_batch(&mut self, b: usize) {
        let b = b.max(1);
        if self.batch == b {
            return;
        }
        self.batch = b;
        if b > self.batch_cap {
            self.batch_cap = b;
            let cap = self.n * b * self.m;
            self.v.resize(cap, 0.0);
            self.psi.resize(cap, 0.0);
        }
        // Cold start: the row stride changed, so the active region holds
        // stale bytes from the previous shape.
        let active = self.n * b * self.m;
        self.v[..active].fill(0.0);
        self.psi[..active].fill(0.0);
    }

    /// Allocation high-water mark: the largest batch size the iterate
    /// buffers are currently sized for.
    pub fn batch_capacity(&self) -> usize {
        self.batch_cap
    }

    fn ensure_scratch(&mut self, threads: usize, atoms: usize) {
        self.reserve_atoms(atoms);
        if threads > 1 {
            let want = atoms * self.batch;
            if self.worker_thr.len() < threads {
                self.worker_thr.resize_with(threads, Vec::new);
            }
            for t in &mut self.worker_thr[..threads] {
                if t.len() < want {
                    t.resize(want, 0.0);
                }
            }
        }
    }

    /// Number of active elements in `V`/`Ψ` (`N·B·M`).
    #[inline]
    fn active_len(&self) -> usize {
        self.n * self.batch * self.m
    }

    /// Reset all dual iterates to zero (cold start for a new sample or
    /// minibatch).
    pub fn reset(&mut self) {
        let active = self.active_len();
        self.v[..active].fill(0.0);
    }

    /// Warm start: every *informed* agent initializes its dual iterate at
    /// `scale · x` locally (no communication — the agent already holds
    /// `x`). With `scale = 1/c_f` this jumps straight to the `y = 0`
    /// stationary point `ν = f'(x)`'s linear regime, skipping the slow
    /// O(N/(μ·c_f)) magnitude build-up that dominates cold-start Huber
    /// runs. Uninformed agents stay at zero and catch up via combine.
    pub fn reset_warm(&mut self, x: &[f32], scale: f32) {
        self.reset_warm_batch(&[x], scale);
    }

    /// Batched [`Self::reset_warm`]: sample `s` of the minibatch starts at
    /// `scale · xs[s]` on informed agents, zero elsewhere.
    pub fn reset_warm_batch(&mut self, xs: &[&[f32]], scale: f32) {
        self.reserve_batch(xs.len());
        let m = self.m;
        let bm = self.batch * m;
        for k in 0..self.n {
            let informed = self.theta[k] > 0.0;
            let row = &mut self.v[k * bm..(k + 1) * bm];
            if informed {
                for (s, &x) in xs.iter().enumerate() {
                    debug_assert_eq!(x.len(), m);
                    for (r, &xi) in row[s * m..(s + 1) * m].iter_mut().zip(x) {
                        *r = scale * xi;
                    }
                }
            } else {
                row.fill(0.0);
            }
        }
    }

    /// Run `params.iters` diffusion iterations for data sample `x`.
    ///
    /// Returns after convergence; read results through [`Self::nu`],
    /// [`Self::consensus_nu`], or [`Self::recover_y`].
    pub fn run(
        &mut self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        x: &[f32],
        params: DiffusionParams,
    ) -> Result<()> {
        self.run_batch(dict, task, &[x], params)
    }

    /// Run `params.iters` diffusion iterations for a minibatch of samples,
    /// stacked as `V ∈ R^{N×(B·M)}` so one combine and one worker-pool
    /// sweep serve all `B` samples. Sample `s`'s trajectory is bit-identical
    /// to a sequential [`Self::run`] on `xs[s]` at any thread count.
    ///
    /// Re-shapes the iterates when `B` differs from the previous call (a
    /// cold start); otherwise the previous batch state is kept, exactly as
    /// [`Self::run`] keeps `V` — call [`Self::reset`] for a cold start.
    /// Read per-sample results through [`Self::nu_sample`],
    /// [`Self::recover_y_sample`], or [`Self::consensus_nu_sample_into`].
    pub fn run_batch(
        &mut self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        xs: &[&[f32]],
        params: DiffusionParams,
    ) -> Result<()> {
        if xs.is_empty() {
            return Err(DdlError::Shape("run_batch: empty minibatch".into()));
        }
        for x in xs {
            if x.len() != self.m {
                return Err(DdlError::Shape(format!(
                    "sample length {} != engine dimension {}",
                    x.len(),
                    self.m
                )));
            }
        }
        if dict.agents() != self.n {
            return Err(DdlError::Shape(format!(
                "dictionary has {} agents, engine {}",
                dict.agents(),
                self.n
            )));
        }
        if dict.m() != self.m {
            return Err(DdlError::Shape("dictionary row dimension mismatch".into()));
        }
        self.reserve_batch(xs.len());
        let mut threads = params.threads.max(1).min(self.n.max(1));
        if let Some(pool) = &self.pool {
            threads = threads.min(pool.threads());
        }
        self.ensure_scratch(threads, dict.k());
        if threads == 1 {
            self.run_serial(dict, task, xs, params)
        } else {
            self.run_parallel(dict, task, xs, params, threads)
        }
        Ok(())
    }

    fn run_serial(
        &mut self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        xs: &[&[f32]],
        params: DiffusionParams,
    ) {
        let n = self.n;
        let cf_over_n = task.conj_grad_scale() / n as f32;
        let inv_delta = 1.0 / task.delta();
        let mu = params.mu;
        let clip = task.dual_clip();
        let bm = self.batch * self.m;
        let active = n * bm;
        let thr_len = dict.k() * self.batch;
        // Disjoint field borrows for the V-shared / Ψ-mut / thr-mut adapt
        // call (the buffers are grow-only, so only the leading prefixes are
        // active).
        let DiffusionEngine { v, psi, thr, theta, combine, .. } = self;
        let v = &mut v[..active];
        let psi = &mut psi[..active];
        let thr = &mut thr[..thr_len];

        for _ in 0..params.iters {
            // --- adapt (Eq. 31a): ψ_k = ν_k − μ ∇J_k(ν_k), per sample ---
            for k in 0..n {
                adapt_row_batch(
                    dict,
                    task,
                    xs,
                    theta[k],
                    k,
                    &v[k * bm..(k + 1) * bm],
                    &mut psi[k * bm..(k + 1) * bm],
                    thr,
                    mu,
                    cf_over_n,
                    inv_delta,
                );
            }
            // --- combine (Eq. 31b): V ← Aᵀ Ψ, all samples at once ---
            match combine {
                Combine::Uniform => uniform_combine(v, psi, n, bm),
                Combine::Sparse(at) => at.spmm_rows(0..n, psi, bm, v),
                Combine::Dense(at) => {
                    blas::gemm(n, bm, n, 1.0, at.as_slice(), psi, 0.0, v)
                }
            }
            // --- projection onto V_f (Eq. 35b), Huber only ---
            if let Some(bound) = clip {
                clip_linf(v, bound);
            }
        }
    }

    /// Threaded run: one SPMD region per call (threads spawn once, not per
    /// iteration), two barriers per iteration. Worker `w` owns the agent
    /// rows `chunk_range(n, threads, w)` for both adapt and combine, so
    /// every `V`/`Ψ` row is produced by exactly one worker with serial-path
    /// arithmetic — trajectories are bit-identical to `threads = 1`. The
    /// batch widens each row to `B·M` columns, amortizing both barriers and
    /// the thread spawn across the whole minibatch.
    fn run_parallel(
        &mut self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        xs: &[&[f32]],
        params: DiffusionParams,
        threads: usize,
    ) {
        let n = self.n;
        let bm = self.batch * self.m;
        let active = n * bm;
        let thr_len = dict.k() * self.batch;
        let mu = params.mu;
        let iters = params.iters;
        let cf_over_n = task.conj_grad_scale() / n as f32;
        let inv_delta = 1.0 / task.delta();
        let clip = task.dual_clip();

        // Disjoint field borrows, materialized before the SPMD closure.
        let pool = self.pool.clone();
        let DiffusionEngine { v, psi, combine, theta, worker_thr, .. } = self;
        let v_sh = SharedRows::new(&mut v[..active]);
        let psi_sh = SharedRows::new(&mut psi[..active]);
        let combine: &Combine = combine;
        let theta: &[f32] = theta.as_slice();
        let barrier = Barrier::new(threads);

        let body = |w: usize, thr_buf: &mut Vec<f32>| {
            let thr = &mut thr_buf[..thr_len];
            let rows = chunk_range(n, threads, w);
            for _ in 0..iters {
                // Adapt phase: this worker writes only its own Ψ rows and
                // reads only its own V rows.
                for k in rows.clone() {
                    // SAFETY: row k belongs to this worker's chunk; V rows
                    // were last written by the same worker (combine phase),
                    // ordered by the barrier below.
                    let nu = unsafe { v_sh.rows(k, 1, bm) };
                    let psi_k = unsafe { psi_sh.rows_mut(k, 1, bm) };
                    adapt_row_batch(
                        dict, task, xs, theta[k], k, nu, psi_k, thr, mu, cf_over_n, inv_delta,
                    );
                }
                // All Ψ rows written before anyone reads them.
                barrier.wait();
                // Combine phase: read all of Ψ, write own V rows.
                match combine {
                    Combine::Uniform => {
                        // O(N·B·M) total — not worth splitting; worker 0
                        // does it serially (bit-identical to the serial
                        // path).
                        if w == 0 {
                            // SAFETY: only worker 0 touches V this phase;
                            // Ψ is read-only for everyone.
                            let v_all = unsafe { v_sh.rows_mut(0, n, bm) };
                            let psi_all = unsafe { psi_sh.rows(0, n, bm) };
                            uniform_combine(v_all, psi_all, n, bm);
                            if let Some(bound) = clip {
                                clip_linf(v_all, bound);
                            }
                        }
                    }
                    Combine::Sparse(at) => {
                        if !rows.is_empty() {
                            // SAFETY: V row windows are disjoint per worker;
                            // Ψ is read-only until the next barrier.
                            let psi_all = unsafe { psi_sh.rows(0, n, bm) };
                            let v_rows = unsafe { v_sh.rows_mut(rows.start, rows.len(), bm) };
                            at.spmm_rows(rows.clone(), psi_all, bm, v_rows);
                            if let Some(bound) = clip {
                                clip_linf(v_rows, bound);
                            }
                        }
                    }
                    Combine::Dense(at) => {
                        if !rows.is_empty() {
                            // SAFETY: as in the sparse arm.
                            let psi_all = unsafe { psi_sh.rows(0, n, bm) };
                            let v_rows = unsafe { v_sh.rows_mut(rows.start, rows.len(), bm) };
                            let a_rows = &at.as_slice()[rows.start * n..rows.end * n];
                            blas::gemm(rows.len(), bm, n, 1.0, a_rows, psi_all, 0.0, v_rows);
                            if let Some(bound) = clip {
                                clip_linf(v_rows, bound);
                            }
                        }
                    }
                }
                // V complete and Ψ free for the next adapt phase.
                barrier.wait();
            }
        };
        match &pool {
            Some(p) => p.spmd_with_active(threads, &mut worker_thr[..threads], body),
            None => WorkerPool::new(threads).spmd_with(&mut worker_thr[..threads], body),
        }
    }

    /// Read-only view of the active stacked dual iterates — the engine's
    /// whole readout surface as a value that can be cloned out and shipped
    /// to another pipeline stage ([`NuView`]).
    pub fn nu_view(&self) -> NuView<'_> {
        NuView::new(&self.v[..self.active_len()], self.n, self.m, self.batch)
    }

    /// Agent `k`'s current dual estimate `ν_{k,i}` (first sample of a
    /// batched run).
    pub fn nu(&self, k: usize) -> &[f32] {
        self.nu_sample(k, 0)
    }

    /// Agent `k`'s dual estimate for sample `s` of the current minibatch.
    pub fn nu_sample(&self, k: usize, s: usize) -> &[f32] {
        debug_assert!(s < self.batch);
        &self.v[k * self.batch * self.m + s * self.m..][..self.m]
    }

    /// Current batch size `B`.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Network-average dual estimate (diagnostics; a real deployment reads
    /// any single agent after convergence).
    pub fn consensus_nu(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.m];
        self.consensus_nu_into(&mut out);
        out
    }

    /// Allocation-free variant of [`Self::consensus_nu`]: write the
    /// network-average dual estimate into a caller-provided buffer of
    /// length `M` (streaming loops reuse one buffer across samples).
    pub fn consensus_nu_into(&self, out: &mut [f32]) {
        self.consensus_nu_sample_into(0, out);
    }

    /// Per-sample [`Self::consensus_nu_into`] for batched runs.
    pub fn consensus_nu_sample_into(&self, s: usize, out: &mut [f32]) {
        self.nu_view().consensus_into(s, out);
    }

    /// Maximum pairwise disagreement `max_k ‖ν_k − ν̄‖` — a consensus
    /// diagnostic (first sample of a batched run).
    pub fn disagreement(&self) -> f32 {
        self.disagreement_sample(0)
    }

    /// Per-sample [`Self::disagreement`] for batched runs.
    pub fn disagreement_sample(&self, s: usize) -> f32 {
        let mut mean = vec![0.0f32; self.m];
        self.disagreement_sample_into(s, &mut mean)
    }

    /// Allocation-free [`Self::disagreement_sample`]: `mean` is a
    /// caller-provided `M`-length scratch buffer (overwritten with the
    /// consensus estimate).
    pub fn disagreement_sample_into(&self, s: usize, mean: &mut [f32]) -> f32 {
        self.nu_view().disagreement_into(s, mean)
    }

    /// Primal recovery (Eq. 37 / Table II): `y_q = thr_γ(w_qᵀ ν_k)/δ` for
    /// each agent's own atoms, using each agent's **local** dual iterate —
    /// no extra communication, exactly as in Algs. 2–4.
    pub fn recover_y(&self, dict: &DistributedDictionary, task: &TaskSpec) -> Vec<f32> {
        self.recover_y_sample(dict, task, 0)
    }

    /// Per-sample [`Self::recover_y`] for batched runs.
    pub fn recover_y_sample(
        &self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        s: usize,
    ) -> Vec<f32> {
        let mut y = vec![0.0f32; dict.k()];
        let mut scratch = vec![0.0f32; dict.k()];
        self.recover_y_sample_into(dict, task, s, &mut y, &mut scratch);
        y
    }

    /// Allocation-free per-sample primal recovery: `y` and `scratch` are
    /// caller-provided `K`-length buffers (streaming loops reuse them).
    /// Delegates to [`recover_y_into`] over the live [`Self::nu_view`].
    pub fn recover_y_sample_into(
        &self,
        dict: &DistributedDictionary,
        task: &TaskSpec,
        s: usize,
        y: &mut [f32],
        scratch: &mut [f32],
    ) {
        recover_y_into(dict, task, &self.nu_view(), s, y, scratch);
    }

    /// Whether the fully-connected fast path is active.
    pub fn is_fully_connected(&self) -> bool {
        matches!(self.combine, Combine::Uniform)
    }

    /// Which combine path is installed: `"uniform"`, `"sparse"`, or
    /// `"dense"`.
    pub fn combine_path(&self) -> &'static str {
        self.combine.path()
    }

    /// Number of agents.
    pub fn agents(&self) -> usize {
        self.n
    }

    /// Data dimension.
    pub fn dim(&self) -> usize {
        self.m
    }
}

/// Informed-agent mask θ (Eq. 29); shared with the actor executor.
pub(crate) fn build_theta(n: usize, informed: Option<&[usize]>) -> Result<Vec<f32>> {
    let mut theta = vec![0.0f32; n];
    match informed {
        None => theta.fill(1.0 / n as f32),
        Some(idx) => {
            if idx.is_empty() {
                return Err(DdlError::Config("at least one informed agent required".into()));
            }
            let w = 1.0 / idx.len() as f32;
            for &k in idx {
                if k >= n {
                    return Err(DdlError::Config(format!("informed agent {k} out of range")));
                }
                theta[k] = w;
            }
        }
    }
    Ok(theta)
}

/// Push-sum (ratio-of-sums) consensus over a **column-stochastic** weight
/// matrix `a` (e.g. [`crate::graph::pushsum_weights_live`]): iterate
/// `s ← A s`, `w ← A w` from `s = values`, `w = 1`, and return each
/// agent's estimate `s_k / w_k` after `iters` steps. `values` is row-major
/// `n × m`. This is the matrix-form reference for the per-edge push-sum
/// combine in [`crate::net::async_exec`]: on a connected live digraph the
/// ratios converge to the true network average even where plain
/// row-normalized averaging is biased (`ddl chaos`, directed outages).
pub fn pushsum_ratio_consensus(a: &Mat, values: &[f32], n: usize, m: usize, iters: usize) -> Vec<f32> {
    assert_eq!(a.rows(), n);
    assert_eq!(a.cols(), n);
    assert_eq!(values.len(), n * m);
    let mut s = values.to_vec();
    let mut w = vec![1.0f32; n];
    let mut s2 = vec![0.0f32; n * m];
    let mut w2 = vec![0.0f32; n];
    for _ in 0..iters {
        s2.fill(0.0);
        w2.fill(0.0);
        for k in 0..n {
            for l in 0..n {
                let alk = a.get(l, k);
                if alk == 0.0 {
                    continue;
                }
                let src = &s[k * m..(k + 1) * m];
                let dst = &mut s2[l * m..(l + 1) * m];
                for i in 0..m {
                    dst[i] += alk * src[i];
                }
                w2[l] += alk * w[k];
            }
        }
        std::mem::swap(&mut s, &mut s2);
        std::mem::swap(&mut w, &mut w2);
    }
    for k in 0..n {
        let inv = 1.0 / w[k].max(1e-12);
        for i in 0..m {
            s[k * m + i] *= inv;
        }
    }
    s
}

/// Trimmed weighted mean of one coordinate's `(value, weight)` entries —
/// the aggregation primitive of the Byzantine-resilient combine
/// ([`crate::net::CombineMode::Median`] / `TrimmedMean(f)`).
///
/// Entries are sorted by value with [`f32::total_cmp`] (a total order, so
/// ties — including `±0.0` — break deterministically and every replay
/// sorts identically); the `g` smallest and `g` largest are discarded,
/// where `g = min(f, ⌊(len−1)/2⌋)` for `TrimmedMean(f)` (`trim =
/// Some(f)`) and `g = ⌊(len−1)/2⌋` for `Median` (`trim = None` — at most
/// two middle entries survive); the survivors' weighted mean is returned
/// with weights renormalized to sum to one. A single survivor is
/// returned exactly (no `w·v/w` round-trip), so the weighted median of
/// an odd count is bit-exact. The slice is reordered in place (it is
/// scratch).
pub fn trimmed_weighted_mean(entries: &mut [(f32, f32)], trim: Option<usize>) -> f32 {
    if entries.is_empty() {
        return 0.0;
    }
    entries.sort_by(|a, b| a.0.total_cmp(&b.0));
    let cap = (entries.len() - 1) / 2;
    let g = trim.map_or(cap, |f| f.min(cap));
    let kept = &entries[g..entries.len() - g];
    if kept.len() == 1 {
        return kept[0].0;
    }
    let wsum: f32 = kept.iter().map(|e| e.1).sum();
    let inv = 1.0 / wsum.max(1e-12);
    kept.iter().map(|e| e.1 * inv * e.0).sum()
}

/// Matrix-form reference for the resilient combine: one synchronous round
/// of the coordinate-wise trimmed weighted mean over `a`'s columns, the
/// robust counterpart of one `ν = Aᵀψ` Metropolis round. For each agent
/// `k` the participants are itself plus every in-neighbor `l` with
/// `a[l][k] > 0`, each carrying its combination weight; per coordinate
/// the estimate is [`trimmed_weighted_mean`] with `trim` as above.
/// `values` is row-major `n × m`. Mirrors [`pushsum_ratio_consensus`]'s
/// role for the push-sum combine: the async executor's per-edge
/// arithmetic, restated without the event machinery.
pub fn resilient_combine(
    a: &Mat,
    values: &[f32],
    n: usize,
    m: usize,
    trim: Option<usize>,
) -> Vec<f32> {
    assert_eq!(a.rows(), n);
    assert_eq!(a.cols(), n);
    assert_eq!(values.len(), n * m);
    let mut out = vec![0.0f32; n * m];
    let mut scratch: Vec<(f32, f32)> = Vec::with_capacity(n);
    for k in 0..n {
        let parts: Vec<(usize, f32)> = (0..n)
            .filter_map(|l| {
                let w = a.get(l, k);
                if l == k || w > 0.0 {
                    Some((l, w))
                } else {
                    None
                }
            })
            .collect();
        for i in 0..m {
            scratch.clear();
            scratch.extend(parts.iter().map(|&(l, w)| (values[l * m + i], w)));
            out[k * m + i] = trimmed_weighted_mean(&mut scratch, trim);
        }
    }
    out
}

/// [`resilient_combine`] with the detection-and-exclusion scoring law of
/// the async executor's `combine_resilient`, restated in matrix form —
/// the BSP-side mirror used to unit-test the evidence rule without the
/// event machinery. `scores` and `excluded` are `n × n` row-major
/// reputation state (`[judge * n + suspect]`), carried by the caller
/// across rounds; `iter` is the round index (the evidence pass arms at
/// `det.warmup_iters`). Per judge the participants are itself plus every
/// in-neighbor not yet excluded *by that judge*; the aggregate arithmetic
/// is exactly [`resilient_combine`]'s (a separate augmented sort does the
/// tail attribution), so with detection disabled — or enabled against
/// zero attackers — the output is bit-for-bit `resilient_combine` over
/// the same participant sets. Evidence per round requires all three
/// [`crate::net::DetectionConfig`] conditions (trimmed-tail membership
/// fraction, distance dominance over the median participant, distance
/// significance against the aggregate's L1 scale); evidence increments
/// the score, a clean round resets it, and crossing `exclude_after`
/// excludes the suspect permanently (probation is a sim-time concept the
/// round-indexed mirror does not model).
#[allow(clippy::too_many_arguments)]
pub fn resilient_combine_detect(
    a: &Mat,
    values: &[f32],
    n: usize,
    m: usize,
    trim: Option<usize>,
    iter: usize,
    det: &crate::net::chaos::DetectionConfig,
    scores: &mut [usize],
    excluded: &mut [bool],
) -> Vec<f32> {
    assert_eq!(a.rows(), n);
    assert_eq!(a.cols(), n);
    assert_eq!(values.len(), n * m);
    assert_eq!(scores.len(), n * n);
    assert_eq!(excluded.len(), n * n);
    let mut out = vec![0.0f32; n * m];
    let mut scratch: Vec<(f32, f32)> = Vec::with_capacity(n);
    let mut order: Vec<(f32, usize)> = Vec::with_capacity(n);
    for k in 0..n {
        let parts: Vec<(usize, f32)> = (0..n)
            .filter_map(|l| {
                let w = a.get(l, k);
                if l == k || (w > 0.0 && !(det.enabled && excluded[k * n + l])) {
                    Some((l, w))
                } else {
                    None
                }
            })
            .collect();
        let pn = parts.len();
        let cap = pn.saturating_sub(1) / 2;
        let g = trim.map_or(cap, |f| f.min(cap));
        let score_pass = det.enabled && pn > 1 && iter >= det.warmup_iters;
        let mut tail_hits = vec![0usize; pn];
        for i in 0..m {
            scratch.clear();
            scratch.extend(parts.iter().map(|&(l, w)| (values[l * m + i], w)));
            if score_pass && g > 0 {
                order.clear();
                order.extend(parts.iter().enumerate().map(|(p, &(l, _))| (values[l * m + i], p)));
                order.sort_by(|x, y| x.0.total_cmp(&y.0));
                for &(_, p) in order[..g].iter().chain(order[pn - g..].iter()) {
                    tail_hits[p] += 1;
                }
            }
            out[k * m + i] = trimmed_weighted_mean(&mut scratch, trim);
        }
        if score_pass {
            let nu_k = &out[k * m..(k + 1) * m];
            let dist: Vec<f64> = parts
                .iter()
                .map(|&(l, _)| {
                    (0..m).map(|i| (values[l * m + i] - nu_k[i]).abs() as f64).sum()
                })
                .collect();
            let mut sorted = dist.clone();
            sorted.sort_by(f64::total_cmp);
            let med = sorted[(pn - 1) / 2].max(1e-12);
            let nu_l1: f64 = nu_k.iter().map(|v| v.abs() as f64).sum();
            for (p, &(l, _)) in parts.iter().enumerate() {
                if l == k {
                    continue;
                }
                let tail_frac = tail_hits[p] as f64 / m.max(1) as f64;
                let evidence = tail_frac >= det.tail_frac_min
                    && dist[p] >= det.dist_ratio * med
                    && dist[p] >= det.rel_dist_min * (nu_l1 + 1e-6);
                let s = &mut scores[k * n + l];
                if evidence {
                    *s += 1;
                    if *s >= det.exclude_after {
                        excluded[k * n + l] = true;
                    }
                } else {
                    *s = 0;
                }
            }
        }
    }
    out
}

/// One agent's adapt step (Eq. 31a) over the whole minibatch, shared
/// verbatim by the serial and threaded paths so their per-row arithmetic
/// is identical. `nu`/`psi` are the agent's `B·M` row windows; `thr` is
/// the `K·B` threshold scratch (layout `[q·B + s]`), of which only agent
/// `k`'s block is read back. Per-sample arithmetic runs in the exact order
/// of the single-sample step, so each sample's ψ is bit-identical to a
/// sequential run.
#[allow(clippy::too_many_arguments)]
#[inline]
fn adapt_row_batch(
    dict: &DistributedDictionary,
    task: &TaskSpec,
    xs: &[&[f32]],
    theta_k: f32,
    k: usize,
    nu: &[f32],
    psi: &mut [f32],
    thr: &mut [f32],
    mu: f32,
    cf_over_n: f32,
    inv_delta: f32,
) {
    let b = xs.len();
    let m = dict.m();
    // s_{q,s} = w_qᵀ ν_{k,s}, thresholded and pre-scaled by −μ/δ. The
    // batched correlation walks each strided W column once for all samples.
    dict.block_correlations_batched(k, nu, b, thr);
    let (start, len) = dict.block(k);
    for q in start..start + len {
        for s in 0..b {
            thr[q * b + s] = task.threshold(thr[q * b + s]) * (-mu * inv_delta);
        }
    }
    // ψ_s = ν_s − μ(c_f/N · ν_s − θ_k x_s), per sample segment.
    for (s, &x) in xs.iter().enumerate() {
        let nu_s = &nu[s * m..(s + 1) * m];
        let psi_s = &mut psi[s * m..(s + 1) * m];
        for (i, p) in psi_s.iter_mut().enumerate() {
            *p = nu_s[i] - mu * (cf_over_n * nu_s[i] - theta_k * x[i]);
        }
    }
    // ψ_s -= (μ/δ) Σ_q thr(s_{q,s}) w_q  — only agent k's atoms.
    dict.block_accumulate_batched(k, thr, b, psi);
}

/// Fully-connected combine: every row of `AᵀΨ` equals the column mean of
/// `Ψ` — `O(N·M)` instead of `O(N²·M)`.
fn uniform_combine(v: &mut [f32], psi: &[f32], n: usize, m: usize) {
    let inv_n = 1.0 / n as f32;
    v[..m].fill(0.0);
    for k in 0..n {
        let row = &psi[k * m..(k + 1) * m];
        for i in 0..m {
            v[i] += row[i];
        }
    }
    for i in 0..m {
        v[i] *= inv_n;
    }
    let (first, rest) = v.split_at_mut(m);
    for k in 1..n {
        rest[(k - 1) * m..k * m].copy_from_slice(first);
    }
}

/// Detect `A = (1/N)·11ᵀ` (all entries equal and doubly stochastic).
fn is_uniform(a: &Mat) -> bool {
    let n = a.rows();
    if n == 0 || a.cols() != n {
        return false;
    }
    let expect = 1.0 / n as f32;
    a.as_slice().iter().all(|&v| (v - expect).abs() <= 1e-7 * (1.0 + expect))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_csr, metropolis_weights, uniform_weights, Graph, Topology};
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    fn setup(n: usize, m: usize, seed: u64) -> (DistributedDictionary, Mat, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let x: Vec<f32> = rng.normal_vec(m);
        (dict, a, x)
    }

    /// Push-sum ratio consensus recovers the exact average under a
    /// directed live mask, where row-normalized averaging over the same
    /// digraph is biased — the correction `ddl chaos` relies on.
    #[test]
    fn pushsum_ratio_consensus_unbiased_on_digraph() {
        let n = 12usize;
        let m = 3usize;
        let mut rng = Pcg64::new(17);
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        // Directed mask: three one-way outages.
        let alive = |k: usize, l: usize| {
            !((k == 0 && l == 1) || (k == 4 && l == 6) || (k == 9 && l == 8))
        };
        let a = crate::graph::pushsum_weights_live(&g, alive);
        let values: Vec<f32> = (0..n * m).map(|_| rng.next_normal()).collect();
        let z = pushsum_ratio_consensus(&a, &values, n, m, 600);
        for i in 0..m {
            let mean: f32 = (0..n).map(|k| values[k * m + i]).sum::<f32>() / n as f32;
            for k in 0..n {
                assert!(
                    (z[k * m + i] - mean).abs() < 1e-3,
                    "agent {k} dim {i}: {} vs {mean}",
                    z[k * m + i]
                );
            }
        }
    }

    /// The trimmed weighted mean: median semantics, trim clamping,
    /// renormalization, and deterministic behavior on ties.
    #[test]
    fn trimmed_weighted_mean_semantics() {
        // Median (trim = None) of an odd count returns the middle value
        // bit-exactly, whatever its weight.
        let mut e = [(5.0f32, 0.1f32), (1.0, 0.5), (3.0, 0.4)];
        assert_eq!(trimmed_weighted_mean(&mut e, None), 3.0);
        // trim = 0 is the plain weighted mean (weights renormalized).
        let mut e = [(1.0f32, 0.25f32), (3.0, 0.25)];
        assert!((trimmed_weighted_mean(&mut e, Some(0)) - 2.0).abs() < 1e-6);
        // trim = 1 discards the extremes: the outlier cannot move the
        // aggregate outside the honest range.
        let mut e = [(0.0f32, 0.3f32), (1.0, 0.3), (1_000.0, 0.4)];
        let v = trimmed_weighted_mean(&mut e, Some(1));
        assert_eq!(v, 1.0, "single survivor returned exactly");
        // trim larger than the population clamps to the median.
        let mut e = [(0.0f32, 0.5f32), (2.0, 0.5), (4.0, 0.5)];
        assert_eq!(trimmed_weighted_mean(&mut e, Some(10)), 2.0);
        // Ties sort deterministically (total_cmp is a total order), so
        // repeated calls agree bitwise.
        let mut a = [(1.0f32, 0.2f32), (1.0, 0.8), (2.0, 0.5)];
        let mut b = a;
        assert_eq!(
            trimmed_weighted_mean(&mut a, Some(1)).to_bits(),
            trimmed_weighted_mean(&mut b, Some(1)).to_bits()
        );
        // Empty input is defined (0.0) rather than a panic.
        assert_eq!(trimmed_weighted_mean(&mut [], None), 0.0);
    }

    /// The matrix-form resilient combine resists a single outlier agent
    /// where the plain Metropolis round is dragged by it, and with
    /// trim = 0 every surviving estimate stays inside the value range
    /// (it is a convex combination).
    #[test]
    fn resilient_combine_resists_outlier() {
        let n = 8usize;
        let m = 2usize;
        let mut rng = Pcg64::new(23);
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        // Honest values in [0, 1]; agent 3 reports a huge constant.
        let mut values: Vec<f32> = (0..n * m).map(|_| rng.next_f32()).collect();
        for i in 0..m {
            values[3 * m + i] = 1e6;
        }
        let robust = resilient_combine(&a, &values, n, m, Some(1));
        for k in 0..n {
            if k == 3 {
                continue; // the liar's own estimate is its own problem
            }
            for i in 0..m {
                assert!(
                    (0.0..=1.0).contains(&robust[k * m + i]),
                    "agent {k} dim {i}: trimmed estimate {} left the honest range",
                    robust[k * m + i]
                );
            }
        }
        // trim = 0 on honest values: convex combination stays in range
        // and a repeat call replays bitwise.
        let honest: Vec<f32> = (0..n * m).map(|_| rng.next_f32()).collect();
        let z1 = resilient_combine(&a, &honest, n, m, Some(0));
        let z2 = resilient_combine(&a, &honest, n, m, Some(0));
        for (v1, v2) in z1.iter().zip(&z2) {
            assert_eq!(v1.to_bits(), v2.to_bits());
        }
        for v in &z1 {
            assert!((0.0..=1.0).contains(v));
        }
    }

    /// The matrix-form detection mirror: a persistent sign-flip agent is
    /// excluded by every judge after `warmup + exclude_after` rounds,
    /// honest agents accumulate no score, and both the detection-off path
    /// and the zero-attacker detection-on path are bit-for-bit
    /// [`resilient_combine`].
    #[test]
    fn resilient_combine_detect_excludes_sign_flipper() {
        let n = 8usize;
        let m = 6usize;
        let mut rng = Pcg64::new(29);
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        let base: Vec<f32> = vec![2.0, -1.5, 3.0, -2.5, 1.0, -3.5];
        let mut values = vec![0.0f32; n * m];
        for k in 0..n {
            for i in 0..m {
                values[k * m + i] = base[i] + 0.01 * rng.next_normal();
            }
        }
        let mut poisoned = values.clone();
        for i in 0..m {
            poisoned[3 * m + i] = -values[3 * m + i];
        }
        let det = crate::net::chaos::DetectionConfig::armed();
        let mut scores = vec![0usize; n * n];
        let mut excluded = vec![false; n * n];
        for iter in 0..det.warmup_iters + det.exclude_after + 3 {
            let out = resilient_combine_detect(
                &a, &poisoned, n, m, Some(1), iter, &det, &mut scores, &mut excluded,
            );
            assert_eq!(out.len(), n * m);
        }
        for k in 0..n {
            for l in 0..n {
                if k == l {
                    continue;
                }
                if l == 3 && a.get(l, k) > 0.0 {
                    assert!(excluded[k * n + l], "judge {k} must exclude the attacker");
                } else {
                    assert!(!excluded[k * n + l], "honest pair ({k},{l}) excluded");
                    assert_eq!(scores[k * n + l], 0, "honest pair ({k},{l}) scored");
                }
            }
        }
        // Post-exclusion the judges aggregate over honest participants
        // only: estimates return to the honest value range.
        let out = resilient_combine_detect(
            &a,
            &poisoned,
            n,
            m,
            Some(1),
            det.warmup_iters + det.exclude_after + 4,
            &det,
            &mut scores,
            &mut excluded,
        );
        for k in 0..n {
            if k == 3 || a.get(3, k) == 0.0 {
                continue;
            }
            for i in 0..m {
                let v = out[k * m + i];
                assert!(
                    (v - base[i]).abs() < 0.1,
                    "judge {k} dim {i}: post-exclusion estimate {v} far from honest {b}",
                    b = base[i]
                );
            }
        }
        // Detection off, and detection on with zero attackers, are both
        // bit-for-bit the plain resilient combine.
        let plain = resilient_combine(&a, &values, n, m, Some(1));
        let off = resilient_combine_detect(
            &a,
            &values,
            n,
            m,
            Some(1),
            100,
            &crate::net::chaos::DetectionConfig::default(),
            &mut vec![0usize; n * n],
            &mut vec![false; n * n],
        );
        let mut s2 = vec![0usize; n * n];
        let mut e2 = vec![false; n * n];
        let on_clean =
            resilient_combine_detect(&a, &values, n, m, Some(1), 100, &det, &mut s2, &mut e2);
        for ((p, o), c) in plain.iter().zip(&off).zip(&on_clean) {
            assert_eq!(p.to_bits(), o.to_bits());
            assert_eq!(p.to_bits(), c.to_bits());
        }
        assert!(e2.iter().all(|&e| !e), "zero-attacker run excluded someone");
    }

    /// Consensus disagreement is O(μ): it must shrink proportionally as μ
    /// shrinks (the diffusion fixed-point property from [17]).
    #[test]
    fn iterates_converge_to_consensus() {
        let (dict, a, x) = setup(8, 12, 1);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 12, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams::new(0.2, 3000)).unwrap();
        let d_big = eng.disagreement();
        eng.reset();
        eng.run(&dict, &task, &x, DiffusionParams::new(0.02, 30_000)).unwrap();
        let d_small = eng.disagreement();
        assert!(d_small < 0.05, "disagreement at small μ: {d_small}");
        assert!(
            d_small < 0.25 * d_big,
            "disagreement must scale with μ: {d_big} → {d_small}"
        );
    }

    /// Fixed point must satisfy the dual optimality condition
    /// Σ_k ∇J_k(ν°) = 0, i.e. ν° − x + (1/δ) W thr(Wᵀν°) = 0 (sq-Euclid).
    #[test]
    fn fixed_point_satisfies_stationarity() {
        let (dict, a, x) = setup(6, 10, 2);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams::new(0.02, 30_000)).unwrap();
        let nu = eng.consensus_nu();
        // grad = ν − x + (1/δ) Σ_q thr(w_qᵀν) w_q
        let s = dict.mat().matvec_t(&nu).unwrap();
        let coeff: Vec<f32> = s.iter().map(|&v| task.threshold(v) / task.delta()).collect();
        let wy = dict.mat().matvec(&coeff).unwrap();
        let mut grad = vec![0.0f32; 10];
        for i in 0..10 {
            grad[i] = nu[i] - x[i] + wy[i];
        }
        // The fixed point sits O(μ) from the optimum (constant step size).
        let gn = crate::math::vector::norm2(&grad);
        assert!(gn < 5e-2, "stationarity residual {gn}");
    }

    /// Eq. 53: at the optimum ν° = x − W y° for the squared-ℓ2 residual.
    #[test]
    fn nu_equals_residual_at_optimum() {
        let (dict, a, x) = setup(6, 10, 3);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams::new(0.02, 30_000)).unwrap();
        let nu = eng.consensus_nu();
        let y = eng.recover_y(&dict, &task);
        let wy = dict.mat().matvec(&y).unwrap();
        for i in 0..10 {
            assert!(
                (nu[i] - (x[i] - wy[i])).abs() < 3e-2,
                "i={i}: ν {} vs residual {}",
                nu[i],
                x[i] - wy[i]
            );
        }
    }

    /// Single informed agent reaches the same solution as all-informed
    /// (the paper's headline distributed-data property).
    #[test]
    fn single_informed_agent_matches_all_informed() {
        let (dict, a, x) = setup(8, 12, 4);
        let task = TaskSpec::SparseCoding { gamma: 0.3, delta: 0.5 };
        // Both configurations share the same optimum; their O(μ) biases
        // differ, so compare at a small step size.
        let params = DiffusionParams::new(0.01, 60_000);
        let mut all = DiffusionEngine::new(&a, 12, None).unwrap();
        all.run(&dict, &task, &x, params).unwrap();
        let mut one = DiffusionEngine::new(&a, 12, Some(&[0])).unwrap();
        one.run(&dict, &task, &x, params).unwrap();
        let na = all.consensus_nu();
        let no = one.consensus_nu();
        crate::testutil::assert_close(&no, &na, 2e-2, 5e-2);
    }

    #[test]
    fn huber_iterates_stay_in_box() {
        let (dict, a, mut x) = setup(6, 10, 5);
        crate::math::vector::scale(5.0, &mut x); // make the box active
        let task = TaskSpec::HuberNmf { gamma: 0.1, delta: 0.5, eta: 0.2 };
        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams::new(0.3, 500)).unwrap();
        for k in 0..6 {
            assert!(crate::math::vector::norm_inf(eng.nu(k)) <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn nmf_recovered_y_nonnegative() {
        let (dict, a, x) = setup(6, 10, 6);
        let task = TaskSpec::Nmf { gamma: 0.05, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams::new(0.3, 1000)).unwrap();
        let y = eng.recover_y(&dict, &task);
        assert!(y.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn fully_connected_consensus_after_one_combine() {
        let (dict, _, x) = setup(5, 8, 7);
        let a = uniform_weights(5);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 8, None).unwrap();
        assert!(eng.is_fully_connected());
        assert_eq!(eng.combine_path(), "uniform");
        eng.run(&dict, &task, &x, DiffusionParams::new(0.3, 1)).unwrap();
        // After combine with A = 11ᵀ/N every row is identical.
        assert!(eng.disagreement() < 1e-6);
    }

    /// The FC fast path must match the generic gemm combine bit-for-bit
    /// in structure (same math, different order — allow f32 roundoff).
    #[test]
    fn fc_fast_path_matches_gemm_combine() {
        let (dict, _, x) = setup(6, 10, 9);
        let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.4 };
        let params = DiffusionParams::new(0.3, 37);
        let a = uniform_weights(6);
        let mut fast = DiffusionEngine::new(&a, 10, None).unwrap();
        assert!(fast.is_fully_connected());
        fast.run(&dict, &task, &x, params).unwrap();
        // Force the dense path by perturbing A negligibly below the doubly-
        // stochastic tolerance but above the uniform-detection threshold.
        let mut a2 = a.clone();
        a2.set(0, 0, a2.get(0, 0) + 3e-6);
        a2.set(0, 1, a2.get(0, 1) - 3e-6);
        let mut slow = DiffusionEngine::new(&a2, 10, None).unwrap();
        assert!(!slow.is_fully_connected());
        assert_eq!(slow.combine_path(), "dense");
        slow.run(&dict, &task, &x, params).unwrap();
        for k in 0..6 {
            crate::testutil::assert_close(fast.nu(k), slow.nu(k), 2e-4, 2e-3);
        }
    }

    /// A ring topology is sparse enough to auto-select the CSR path, and
    /// the result must match the dense-gemm comparator.
    #[test]
    fn sparse_path_matches_dense_combine() {
        let (n, m) = (24, 10);
        let mut rng = Pcg64::new(21);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.25, 80);

        let mut sparse = DiffusionEngine::new(&a, m, None).unwrap();
        assert_eq!(sparse.combine_path(), "sparse");
        sparse.run(&dict, &task, &x, params).unwrap();

        let mut dense = DiffusionEngine::new(&a, m, None).unwrap();
        dense.set_combination_dense(&a).unwrap();
        assert_eq!(dense.combine_path(), "dense");
        dense.run(&dict, &task, &x, params).unwrap();

        for k in 0..n {
            crate::testutil::assert_close(sparse.nu(k), dense.nu(k), 1e-5, 1e-4);
        }
    }

    /// `new_csr` over the direct CSR builder must agree with the dense
    /// constructor on the same topology.
    #[test]
    fn csr_constructor_matches_dense_constructor() {
        // Ring k=3 rows hold 7 entries: density 7/32 < SPARSE_DENSITY_MAX,
        // so both constructors land on the (bit-identical) sparse path.
        let (n, m) = (32, 8);
        let mut rng = Pcg64::new(22);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 3 }, &mut rng);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
        let params = DiffusionParams::new(0.3, 60);

        let mut from_dense = DiffusionEngine::new(&metropolis_weights(&g), m, None).unwrap();
        assert_eq!(from_dense.combine_path(), "sparse");
        from_dense.run(&dict, &task, &x, params).unwrap();
        let mut from_csr = DiffusionEngine::new_csr(metropolis_csr(&g), m, None).unwrap();
        assert_eq!(from_csr.combine_path(), "sparse");
        from_csr.run(&dict, &task, &x, params).unwrap();
        for k in 0..n {
            // Identical weights and identical spmm order → bit-identical.
            assert_eq!(from_dense.nu(k), from_csr.nu(k), "agent {k}");
        }
    }

    /// threads = 1 and threads = 4 must produce *identical* ν trajectories
    /// on every combine path (static row partition, per-row arithmetic
    /// unchanged).
    #[test]
    fn thread_count_does_not_change_trajectory() {
        let (n, m) = (26, 9); // ring k=2 at N=26 → density 5/26 < 0.25
        let mut rng = Pcg64::new(23);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        let x = rng.normal_vec(m);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };

        let installs: Vec<(&str, Box<dyn Fn(&mut DiffusionEngine)>)> = vec![
            ("sparse", Box::new(|e: &mut DiffusionEngine| e.set_combination(&a).unwrap())),
            ("dense", Box::new(|e: &mut DiffusionEngine| e.set_combination_dense(&a).unwrap())),
            (
                "uniform",
                Box::new(|e: &mut DiffusionEngine| e.set_combination(&uniform_weights(n)).unwrap()),
            ),
        ];
        for (label, install) in &installs {
            let mut serial = DiffusionEngine::new(&a, m, None).unwrap();
            install(&mut serial);
            serial.run(&dict, &task, &x, DiffusionParams::new(0.3, 51)).unwrap();
            let mut threaded = DiffusionEngine::new(&a, m, None).unwrap();
            install(&mut threaded);
            threaded
                .run(&dict, &task, &x, DiffusionParams::new(0.3, 51).with_threads(4))
                .unwrap();
            for k in 0..n {
                assert_eq!(serial.nu(k), threaded.nu(k), "{label} path, agent {k}");
            }
        }
    }

    /// The Huber projection must behave identically under threading.
    #[test]
    fn threaded_huber_matches_serial() {
        let (n, m) = (10, 8);
        let mut rng = Pcg64::new(24);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        let mut x = rng.normal_vec(m);
        crate::math::vector::scale(6.0, &mut x);
        let task = TaskSpec::HuberNmf { gamma: 0.1, delta: 0.5, eta: 0.2 };
        let mut serial = DiffusionEngine::new(&a, m, None).unwrap();
        serial.run(&dict, &task, &x, DiffusionParams::new(0.3, 200)).unwrap();
        let mut threaded = DiffusionEngine::new(&a, m, None).unwrap();
        threaded.run(&dict, &task, &x, DiffusionParams::new(0.3, 200).with_threads(3)).unwrap();
        for k in 0..n {
            assert_eq!(serial.nu(k), threaded.nu(k));
            assert!(crate::math::vector::norm_inf(threaded.nu(k)) <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn consensus_nu_into_matches_allocating_variant() {
        let (dict, a, x) = setup(6, 10, 31);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams::new(0.2, 100)).unwrap();
        let alloc = eng.consensus_nu();
        let mut buf = vec![9.9f32; 10];
        eng.consensus_nu_into(&mut buf);
        assert_eq!(alloc, buf);
    }

    /// Batched runs must reproduce each sample's sequential trajectory
    /// bit-for-bit on every combine path.
    #[test]
    fn batched_run_matches_sequential_bitwise() {
        let (n, m, b) = (24, 10, 3);
        let mut rng = Pcg64::new(0xBA7C);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        let xs: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec(m)).collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.3, 47);

        for dense in [false, true] {
            let mut batched = DiffusionEngine::new(&a, m, None).unwrap();
            if dense {
                batched.set_combination_dense(&a).unwrap();
            }
            batched.run_batch(&dict, &task, &refs, params).unwrap();
            assert_eq!(batched.batch(), b);
            for (s, x) in refs.iter().enumerate() {
                let mut seq = DiffusionEngine::new(&a, m, None).unwrap();
                if dense {
                    seq.set_combination_dense(&a).unwrap();
                }
                seq.run(&dict, &task, x, params).unwrap();
                for k in 0..n {
                    assert_eq!(
                        batched.nu_sample(k, s),
                        seq.nu(k),
                        "dense={dense}, sample {s}, agent {k}"
                    );
                }
                assert_eq!(
                    batched.recover_y_sample(&dict, &task, s),
                    seq.recover_y(&dict, &task)
                );
            }
        }

        // Uniform fast path too (fully-connected comparator).
        let u = uniform_weights(n);
        let mut batched = DiffusionEngine::new(&u, m, None).unwrap();
        assert_eq!(batched.combine_path(), "uniform");
        batched.run_batch(&dict, &task, &refs, params).unwrap();
        for (s, x) in refs.iter().enumerate() {
            let mut seq = DiffusionEngine::new(&u, m, None).unwrap();
            seq.run(&dict, &task, x, params).unwrap();
            for k in 0..n {
                assert_eq!(batched.nu_sample(k, s), seq.nu(k), "uniform, sample {s}, agent {k}");
            }
        }
    }

    /// Batched Huber runs keep every per-sample iterate inside the box.
    #[test]
    fn batched_huber_clipped_per_sample() {
        let (n, m, b) = (8, 6, 4);
        let mut rng = Pcg64::new(0xBA7D);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        let xs: Vec<Vec<f32>> = (0..b)
            .map(|_| {
                let mut x = rng.normal_vec(m);
                crate::math::vector::scale(6.0, &mut x);
                x
            })
            .collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
        let task = TaskSpec::HuberNmf { gamma: 0.1, delta: 0.5, eta: 0.2 };
        let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
        eng.run_batch(&dict, &task, &refs, DiffusionParams::new(0.3, 150)).unwrap();
        for k in 0..n {
            for s in 0..b {
                assert!(crate::math::vector::norm_inf(eng.nu_sample(k, s)) <= 1.0 + 1e-6);
            }
        }
    }

    /// Changing batch size re-shapes the iterates; interleaving batched and
    /// single-sample runs keeps single-sample semantics intact.
    #[test]
    fn batch_reshape_roundtrip() {
        let (dict, a, x) = setup(6, 10, 44);
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let params = DiffusionParams::new(0.2, 30);
        let mut reference = DiffusionEngine::new(&a, 10, None).unwrap();
        reference.run(&dict, &task, &x, params).unwrap();

        let mut eng = DiffusionEngine::new(&a, 10, None).unwrap();
        let x2: Vec<f32> = x.iter().map(|v| v * 0.5).collect();
        eng.run_batch(&dict, &task, &[&x, &x2, &x], params).unwrap();
        // Back to a single sample: fresh zero state, same result as a
        // dedicated engine.
        eng.run(&dict, &task, &x, params).unwrap();
        assert_eq!(eng.batch(), 1);
        for k in 0..6 {
            assert_eq!(eng.nu(k), reference.nu(k));
        }
    }

    /// Alternating full and partial batches must reuse the grown buffers
    /// (capacity pinned at the high-water mark) while every run stays
    /// bit-identical to a fresh engine of that batch size.
    #[test]
    fn alternating_batch_sizes_reuse_capacity_bitwise() {
        let (n, m) = (24, 10);
        let mut rng = Pcg64::new(0xA17B);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.3, 25);
        let xs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(m)).collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();

        let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
        for &b in &[8usize, 3, 8, 1, 5, 8] {
            eng.reserve_batch(b);
            eng.reset();
            eng.run_batch(&dict, &task, &refs[..b], params).unwrap();
            assert_eq!(eng.batch(), b);
            assert_eq!(eng.batch_capacity(), 8, "capacity must stay at the high-water mark");
            let mut fresh = DiffusionEngine::new(&a, m, None).unwrap();
            fresh.run_batch(&dict, &task, &refs[..b], params).unwrap();
            for k in 0..n {
                for s in 0..b {
                    assert_eq!(eng.nu_sample(k, s), fresh.nu_sample(k, s), "B={b} k={k} s={s}");
                }
            }
        }
    }

    /// A persistent pool must reproduce the scoped-thread path bit-for-bit
    /// across reused regions and batch-size changes.
    #[test]
    fn persistent_pool_matches_scoped_threads_bitwise() {
        use crate::net::PersistentPool;
        let (n, m) = (26, 9);
        let mut rng = Pcg64::new(0xA17C);
        let dict =
            DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let g = Graph::generate(n, &Topology::Ring { k: 2 }, &mut rng);
        let a = metropolis_weights(&g);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let params = DiffusionParams::new(0.3, 31).with_threads(3);
        let xs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(m)).collect();
        let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();

        let mut pooled = DiffusionEngine::new(&a, m, None).unwrap();
        pooled.set_pool(Arc::new(PersistentPool::new(3)));
        let mut scoped = DiffusionEngine::new(&a, m, None).unwrap();
        for &b in &[4usize, 1, 4] {
            pooled.reset();
            scoped.reset();
            pooled.run_batch(&dict, &task, &refs[..b], params).unwrap();
            scoped.run_batch(&dict, &task, &refs[..b], params).unwrap();
            for k in 0..n {
                for s in 0..b {
                    assert_eq!(pooled.nu_sample(k, s), scoped.nu_sample(k, s), "B={b} k={k} s={s}");
                }
            }
        }
        // A pool smaller than the requested thread count clamps but stays
        // bit-identical (thread count never changes trajectories).
        let mut small = DiffusionEngine::new(&a, m, None).unwrap();
        small.set_pool(Arc::new(PersistentPool::new(2)));
        small.run_batch(&dict, &task, &refs, params).unwrap();
        scoped.reserve_batch(refs.len());
        scoped.reset();
        scoped.run_batch(&dict, &task, &refs, params).unwrap();
        for k in 0..n {
            assert_eq!(small.nu_sample(k, 2), scoped.nu_sample(k, 2));
        }
    }

    /// NuView readouts must agree exactly with the engine's own accessors,
    /// both live and after shipping the buffer to an owned clone.
    #[test]
    fn nu_view_matches_engine_readouts() {
        let (dict, a, x) = setup(8, 12, 77);
        let task = TaskSpec::SparseCoding { gamma: 0.15, delta: 0.5 };
        let mut eng = DiffusionEngine::new(&a, 12, None).unwrap();
        let x2: Vec<f32> = x.iter().map(|v| v * 0.7).collect();
        eng.run_batch(&dict, &task, &[&x, &x2], DiffusionParams::new(0.25, 40)).unwrap();

        let shipped = eng.nu_view().to_owned_data();
        let view = NuView::new(&shipped, 8, 12, 2);
        assert_eq!(view.agents(), 8);
        assert_eq!(view.batch(), 2);
        let mut y_view = vec![0.0f32; dict.k()];
        let mut scratch = vec![0.0f32; dict.k()];
        let mut mean_a = vec![0.0f32; 12];
        let mut mean_b = vec![0.0f32; 12];
        for s in 0..2 {
            for k in 0..8 {
                assert_eq!(view.nu(k, s), eng.nu_sample(k, s));
            }
            recover_y_into(&dict, &task, &view, s, &mut y_view, &mut scratch);
            assert_eq!(y_view, eng.recover_y_sample(&dict, &task, s));
            assert_eq!(
                view.disagreement_into(s, &mut mean_a),
                eng.disagreement_sample_into(s, &mut mean_b)
            );
            assert_eq!(mean_a, mean_b);
        }
    }

    #[test]
    fn empty_batch_rejected() {
        let (dict, a, _) = setup(5, 8, 45);
        let mut eng = DiffusionEngine::new(&a, 8, None).unwrap();
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        assert!(eng
            .run_batch(&dict, &task, &[], DiffusionParams::new(0.1, 1))
            .is_err());
    }

    #[test]
    fn shape_errors_detected() {
        let (dict, a, x) = setup(5, 8, 8);
        let mut eng = DiffusionEngine::new(&a, 8, None).unwrap();
        let task = TaskSpec::SparseCoding { gamma: 0.1, delta: 0.5 };
        let bad_x = vec![0.0; 7];
        assert!(eng.run(&dict, &task, &bad_x, DiffusionParams::new(0.1, 1)).is_err());
        assert!(DiffusionEngine::new(&a, 8, Some(&[9])).is_err());
        assert!(DiffusionEngine::new(&a, 8, Some(&[])).is_err());
        let _ = x;
    }
}
