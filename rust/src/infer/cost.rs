//! Novelty scoring: dual-cost evaluation and the scalar cost-consensus
//! diffusion (Eqs. 59, 63–66).
//!
//! After inference on a test document `h_t`, each agent holds `ν°` and can
//! evaluate its *local* cost `J_k(ν°; h_t)` using only its own atoms. The
//! network then averages the local costs with the scalar diffusion
//! recursion (Eq. 65), converging to `g° = −(1/N)·Σ_k J_k` whose sign-
//! flipped value is a scaled novelty score (the 1/N factor is absorbed
//! into the detection threshold χ).

use crate::math::{blas, Mat};
use crate::model::{DistributedDictionary, TaskSpec};
use crate::net::pool::{chunk_range, SharedRows, WorkerPool};
use std::sync::Barrier;

/// Local dual cost `J_k(ν; x)` of Eq. 29 for agent `k` (all-informed form,
/// Eq. 59: data term weighted 1/N).
pub fn local_cost(
    dict: &DistributedDictionary,
    task: &TaskSpec,
    k: usize,
    nu: &[f32],
    x: &[f32],
    informed_weight: f32,
) -> f32 {
    let n = dict.agents() as f32;
    let (start, len) = dict.block(k);
    let mut s = vec![0.0f32; dict.k()];
    dict.block_correlations(k, nu, &mut s);
    let h = task.h_conj(&s[start..start + len]);
    task.f_conj(nu) / n - informed_weight * blas::dot(nu, x) + h
}

/// Exact sum `Σ_k J_k(ν; x) = f*(ν) − νᵀx + Σ_k h*_k` — the full dual
/// cost (centralized evaluation, used by the fully-connected comparator
/// and by tests).
pub fn dual_cost_sum(dict: &DistributedDictionary, task: &TaskSpec, nu: &[f32], x: &[f32]) -> f32 {
    let s = dict.mat().matvec_t(nu).unwrap();
    task.f_conj(nu) - blas::dot(nu, x) + task.h_conj(&s)
}

/// Scalar cost-consensus diffusion (Eq. 65): given per-agent local costs
/// `j[k] = J_k(ν°; h_t)`, iterate
///
/// ```text
/// φ_k = g_k − μ_g (j_k + g_k)
/// g_k = Σ_ℓ a_{ℓk} φ_ℓ
/// ```
///
/// which converges to `g° = −(1/N) Σ_k j_k` at every agent. Returns the
/// per-agent estimates after `iters` iterations.
pub fn scalar_consensus(a: &Mat, j: &[f32], mu_g: f32, iters: usize) -> Vec<f32> {
    scalar_consensus_threaded(a, j, mu_g, iters, 1)
}

/// [`scalar_consensus`] with a worker-thread count. Agents are partitioned
/// into static row chunks (adapt then combine, one barrier per phase), so
/// the result is bit-identical for every `threads` value. Only pays off
/// for large `N`; `threads = 1` takes the allocation-free serial path.
pub fn scalar_consensus_threaded(
    a: &Mat,
    j: &[f32],
    mu_g: f32,
    iters: usize,
    threads: usize,
) -> Vec<f32> {
    let n = a.rows();
    assert_eq!(a.cols(), n);
    assert_eq!(j.len(), n);
    let mut g = vec![0.0f32; n];
    let mut phi = vec![0.0f32; n];
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        for _ in 0..iters {
            for k in 0..n {
                phi[k] = g[k] - mu_g * (j[k] + g[k]);
            }
            // g = Aᵀ φ
            for k in 0..n {
                let mut acc = 0.0f32;
                for l in 0..n {
                    acc += a.get(l, k) * phi[l];
                }
                g[k] = acc;
            }
        }
        return g;
    }
    {
        let g_sh = SharedRows::new(&mut g);
        let phi_sh = SharedRows::new(&mut phi);
        let barrier = Barrier::new(threads);
        WorkerPool::new(threads).spmd(|w| {
            let rows = chunk_range(n, threads, w);
            for _ in 0..iters {
                {
                    // Adapt: each worker reads and writes only its own rows.
                    // SAFETY: row windows are disjoint per worker; the
                    // barrier below orders them against the combine reads.
                    let g_own = unsafe { g_sh.rows(rows.start, rows.len(), 1) };
                    let phi_own = unsafe { phi_sh.rows_mut(rows.start, rows.len(), 1) };
                    for (i, k) in rows.clone().enumerate() {
                        phi_own[i] = g_own[i] - mu_g * (j[k] + g_own[i]);
                    }
                }
                barrier.wait();
                {
                    // Combine: read all of φ, write own g rows.
                    // SAFETY: φ is read-only until the next barrier; g row
                    // windows are disjoint per worker.
                    let phi_all = unsafe { phi_sh.rows(0, n, 1) };
                    let g_own = unsafe { g_sh.rows_mut(rows.start, rows.len(), 1) };
                    for (i, k) in rows.clone().enumerate() {
                        let mut acc = 0.0f32;
                        for l in 0..n {
                            acc += a.get(l, k) * phi_all[l];
                        }
                        g_own[i] = acc;
                    }
                }
                barrier.wait();
            }
        });
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{metropolis_weights, Graph, Topology};
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    #[test]
    fn local_costs_sum_to_dual_cost() {
        let mut rng = Pcg64::new(1);
        let dict =
            DistributedDictionary::random(10, 6, 6, AtomConstraint::UnitBall, &mut rng).unwrap();
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let nu = rng.normal_vec(10);
        let x = rng.normal_vec(10);
        let total: f32 = (0..6)
            .map(|k| local_cost(&dict, &task, k, &nu, &x, 1.0 / 6.0))
            .sum();
        let direct = dual_cost_sum(&dict, &task, &nu, &x);
        assert!((total - direct).abs() < 1e-3 * (1.0 + direct.abs()), "{total} vs {direct}");
    }

    #[test]
    fn scalar_consensus_converges_to_negative_mean() {
        let mut rng = Pcg64::new(2);
        let g = Graph::generate(10, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let j: Vec<f32> = (0..10).map(|i| i as f32 * 0.1 - 0.3).collect();
        let target = -j.iter().sum::<f32>() / 10.0;
        // Per-agent deviations from −mean(j) are O(μ_g); use a small step.
        let est = scalar_consensus(&a, &j, 0.01, 20_000);
        for (k, &e) in est.iter().enumerate() {
            assert!((e - target).abs() < 1e-2, "agent {k}: {e} vs {target}");
        }
    }

    #[test]
    fn scalar_consensus_threaded_is_bit_identical() {
        let mut rng = Pcg64::new(5);
        let g = Graph::generate(23, &Topology::ErdosRenyi { p: 0.3 }, &mut rng);
        let a = metropolis_weights(&g);
        let j: Vec<f32> = (0..23).map(|i| (i as f32 * 0.37).sin()).collect();
        let serial = scalar_consensus(&a, &j, 0.1, 500);
        for threads in [2, 3, 4] {
            let par = scalar_consensus_threaded(&a, &j, 0.1, 500, threads);
            assert_eq!(serial, par, "threads = {threads}");
        }
    }

    #[test]
    fn scalar_consensus_fully_connected_fast() {
        let a = crate::graph::uniform_weights(5);
        let j = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let est = scalar_consensus(&a, &j, 0.5, 200);
        for &e in &est {
            assert!((e + 3.0).abs() < 1e-3, "{e}");
        }
    }

    /// Novelty separation: a document well modeled by W scores lower than
    /// an orthogonal one.
    #[test]
    fn cost_separates_modeled_from_novel() {
        let mut rng = Pcg64::new(3);
        let dict =
            DistributedDictionary::random(20, 8, 8, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        let task = TaskSpec::Nmf { gamma: 0.05, delta: 0.1 };
        // Modeled doc: positive combination of atoms. Novel doc: random.
        let coeff: Vec<f32> = (0..8).map(|_| rng.next_f32()).collect();
        let mut modeled = dict.mat().matvec(&coeff).unwrap();
        crate::math::vector::normalize(&mut modeled);
        let mut novel: Vec<f32> = rng.normal_vec(20).iter().map(|v| v.abs()).collect();
        crate::math::vector::normalize(&mut novel);
        let score = |x: &[f32]| {
            let sol = crate::infer::exact_dual(&dict, &task, x, 1e-7, 5000).unwrap();
            // Novelty score g(ν°) = −Σ_k J_k = −dual cost; by strong duality
            // this equals the primal optimum — higher = worse fit = novel.
            -dual_cost_sum(&dict, &task, &sol.nu, x)
        };
        let s_mod = score(&modeled);
        let s_nov = score(&novel);
        assert!(
            s_nov > s_mod,
            "novel doc should score higher: modeled {s_mod} vs novel {s_nov}"
        );
    }
}
