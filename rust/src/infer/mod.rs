//! Inference engines for the dual sparse-coding problem (paper §III).
//!
//! * [`diffusion`] — the paper's contribution: ATC diffusion over the dual
//!   (Algs. 1–4), fully distributed, with projected combine for
//!   constrained dual domains.
//! * [`exact`] — FISTA on the dual to machine precision; the CVX
//!   replacement that supplies ground truth `(ν°, y°)` for Fig. 4 and for
//!   the step-size tuning procedure of §IV-A.
//! * [`cost`] — dual-cost evaluation and the scalar cost-consensus
//!   diffusion (Eq. 65) used for distributed novelty scoring.
//!
//! The matrix-form [`DiffusionEngine`] is the compute workhorse; the
//! message-passing executors in [`crate::net`] (BSP, actors, async) run
//! the identical recursion with explicit ψ exchange and are proven
//! equivalent to it — the full executor matrix and the ψ-privacy
//! dataflow diagram live in `ARCHITECTURE.md` at the repository root.

pub mod cost;
pub mod diffusion;
pub mod exact;

pub use cost::{dual_cost_sum, local_cost, scalar_consensus, scalar_consensus_threaded};
pub use diffusion::{
    pushsum_ratio_consensus, recover_y_into, resilient_combine, trimmed_weighted_mean,
    DiffusionEngine, DiffusionParams, NuView, SPARSE_DENSITY_MAX,
};
pub use exact::{exact_dual, ExactSolution};
