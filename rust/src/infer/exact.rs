//! Exact dual solver: FISTA with optional box projection.
//!
//! Replaces the paper's CVX reference (§IV-A) for producing ground-truth
//! `(ν°, y°)` used by the Fig. 4 SNR learning curves and by convergence
//! tests. The dual cost
//!
//! ```text
//! F(ν) = f*(ν) − νᵀx + Σ_q h*_q(w_qᵀν)
//! ```
//!
//! is differentiable with `∇F(ν) = c_f·ν − x + (1/δ)·W thr_γ(Wᵀν)`
//! and Lipschitz constant `L ≤ c_f + σ_max(W)²/δ`, so FISTA converges at
//! the accelerated rate; for the Huber task we project onto the `ℓ∞` box
//! after every step (projected accelerated gradient).

use crate::error::Result;
use crate::math::blas;
use crate::model::{DistributedDictionary, TaskSpec};
use crate::ops::project::clip_linf;

/// Result of an exact dual solve.
#[derive(Clone, Debug)]
pub struct ExactSolution {
    /// Optimal dual variable ν°.
    pub nu: Vec<f32>,
    /// Optimal primal coefficients y° (Eq. 37).
    pub y: Vec<f32>,
    /// Final dual cost `F(ν°)` (= −g(ν°); the primal optimum by strong
    /// duality).
    pub dual_cost: f32,
    /// Final gradient norm (stationarity certificate; for box-constrained
    /// problems this is the projected-gradient norm).
    pub grad_norm: f32,
    /// Iterations used.
    pub iters: usize,
}

/// Dual cost `F(ν)` for the full dictionary.
pub fn dual_cost(dict: &DistributedDictionary, task: &TaskSpec, x: &[f32], nu: &[f32]) -> f32 {
    let s = dict.mat().matvec_t(nu).unwrap();
    task.f_conj(nu) - blas::dot(nu, x) + task.h_conj(&s)
}

/// `∇F(ν)` into `grad`; `s` and `coeff` are scratch of length K.
fn dual_grad(
    dict: &DistributedDictionary,
    task: &TaskSpec,
    x: &[f32],
    nu: &[f32],
    s: &mut Vec<f32>,
    grad: &mut [f32],
) {
    let m = dict.m();
    *s = dict.mat().matvec_t(nu).unwrap();
    let inv_delta = 1.0 / task.delta();
    for v in s.iter_mut() {
        *v = task.threshold(*v) * inv_delta;
    }
    let wy = dict.mat().matvec(s).unwrap();
    let cf = task.conj_grad_scale();
    for i in 0..m {
        grad[i] = cf * nu[i] - x[i] + wy[i];
    }
}

/// Solve the dual to tolerance `tol` on the projected-gradient norm, with
/// at most `max_iters` FISTA iterations.
pub fn exact_dual(
    dict: &DistributedDictionary,
    task: &TaskSpec,
    x: &[f32],
    tol: f32,
    max_iters: usize,
) -> Result<ExactSolution> {
    let m = dict.m();
    assert_eq!(x.len(), m);
    // Lipschitz bound: c_f + σ_max(W)²/δ via power iteration on WᵀW.
    let wt = dict.mat().transpose();
    let gram = wt.matmul(dict.mat()).unwrap(); // K×K = WᵀW
    let (sigma_sq, _) = crate::math::solve::power_iteration(&gram, 100, 0x11F5);
    let lip = task.conj_grad_scale() + sigma_sq.max(0.0) / task.delta();
    let step = 1.0 / lip.max(1e-8);

    let clip = task.dual_clip();
    let mut nu = vec![0.0f32; m];
    let mut z = nu.clone(); // momentum point
    let mut grad = vec![0.0f32; m];
    let mut s: Vec<f32> = Vec::new();
    let mut t = 1.0f32;
    let mut iters = 0;
    let mut gnorm = f32::INFINITY;

    for it in 0..max_iters {
        iters = it + 1;
        dual_grad(dict, task, x, &z, &mut s, &mut grad);
        // ν⁺ = Π(z − step·grad)
        let mut nu_next = vec![0.0f32; m];
        for i in 0..m {
            nu_next[i] = z[i] - step * grad[i];
        }
        if let Some(b) = clip {
            clip_linf(&mut nu_next, b);
        }
        // Projected-gradient stationarity: ‖(ν − Π(ν − step·∇F(ν)))/step‖.
        dual_grad(dict, task, x, &nu_next, &mut s, &mut grad);
        let mut pg = vec![0.0f32; m];
        for i in 0..m {
            pg[i] = nu_next[i] - step * grad[i];
        }
        if let Some(b) = clip {
            clip_linf(&mut pg, b);
        }
        gnorm = (0..m)
            .map(|i| ((nu_next[i] - pg[i]) / step).powi(2))
            .sum::<f32>()
            .sqrt();
        // FISTA momentum.
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let beta = (t - 1.0) / t_next;
        for i in 0..m {
            z[i] = nu_next[i] + beta * (nu_next[i] - nu[i]);
        }
        if let Some(b) = clip {
            clip_linf(&mut z, b);
        }
        nu = nu_next;
        t = t_next;
        if gnorm < tol {
            break;
        }
    }

    // Primal recovery (Eq. 37).
    let mut y = dict.mat().matvec_t(&nu).unwrap();
    let inv_delta = 1.0 / task.delta();
    for v in y.iter_mut() {
        *v = task.threshold(*v) * inv_delta;
    }
    let cost = dual_cost(dict, task, x, &nu);
    Ok(ExactSolution { nu, y, dual_cost: cost, grad_norm: gnorm, iters })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::AtomConstraint;
    use crate::rng::Pcg64;

    fn setup(m: usize, k: usize, seed: u64) -> (DistributedDictionary, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let dict =
            DistributedDictionary::random(m, k, k, AtomConstraint::UnitBall, &mut rng).unwrap();
        let x = rng.normal_vec(m);
        (dict, x)
    }

    #[test]
    fn converges_to_stationarity() {
        let (dict, x) = setup(12, 8, 1);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let sol = exact_dual(&dict, &task, &x, 1e-6, 5000).unwrap();
        assert!(sol.grad_norm < 1e-6, "grad norm {}", sol.grad_norm);
    }

    /// Strong duality: the dual cost equals the primal cost at the
    /// recovered y° (the primal is evaluated directly).
    #[test]
    fn strong_duality_gap_closes() {
        let (dict, x) = setup(10, 6, 2);
        let task = TaskSpec::SparseCoding { gamma: 0.3, delta: 0.4 };
        let sol = exact_dual(&dict, &task, &x, 1e-7, 10000).unwrap();
        let wy = dict.mat().matvec(&sol.y).unwrap();
        let resid = crate::math::vector::sub(&x, &wy);
        let primal = task.f_loss(&resid) + task.h_reg(&sol.y);
        // dual problem: min F(ν) = −g(ν); optimal value −F(ν°) = g(ν°) = primal.
        let dual_value = -sol.dual_cost;
        assert!(
            (primal - dual_value).abs() < 1e-3 * (1.0 + primal.abs()),
            "primal {primal} vs dual {dual_value}"
        );
    }

    /// ν° must equal the residual x − W y° (Eq. 53, squared-ℓ2 case).
    #[test]
    fn nu_is_residual() {
        let (dict, x) = setup(10, 6, 3);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let sol = exact_dual(&dict, &task, &x, 1e-7, 10000).unwrap();
        let wy = dict.mat().matvec(&sol.y).unwrap();
        for i in 0..10 {
            assert!((sol.nu[i] - (x[i] - wy[i])).abs() < 1e-4);
        }
    }

    /// Huber solution stays in the ℓ∞ box and satisfies Eq. 50:
    /// ν° = f'_u(x − Wy°).
    #[test]
    fn huber_box_and_gradient_link() {
        let (dict, mut x) = setup(10, 6, 4);
        crate::math::vector::scale(3.0, &mut x);
        let task = TaskSpec::HuberNmf { gamma: 0.05, delta: 0.5, eta: 0.2 };
        let sol = exact_dual(&dict, &task, &x, 1e-7, 20000).unwrap();
        assert!(crate::math::vector::norm_inf(&sol.nu) <= 1.0 + 1e-5);
        let wy = dict.mat().matvec(&sol.y).unwrap();
        let resid = crate::math::vector::sub(&x, &wy);
        let mut fgrad = vec![0.0; 10];
        task.f_grad(&resid, &mut fgrad);
        crate::testutil::assert_close(&sol.nu, &fgrad, 5e-3, 1e-2);
    }

    /// The diffusion engine must converge to the exact solution.
    #[test]
    fn diffusion_matches_exact() {
        use crate::graph::{metropolis_weights, Graph, Topology};
        let (dict, x) = setup(10, 8, 5);
        let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.5 };
        let exact = exact_dual(&dict, &task, &x, 1e-8, 20000).unwrap();
        let mut rng = Pcg64::new(6);
        let g = Graph::generate(8, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
        let a = metropolis_weights(&g);
        let mut eng = crate::infer::DiffusionEngine::new(&a, 10, None).unwrap();
        eng.run(&dict, &task, &x, crate::infer::DiffusionParams::new(0.02, 40_000))
            .unwrap();
        // The diffusion fixed point is O(μ) from the exact optimum.
        let nu = eng.consensus_nu();
        crate::testutil::assert_close(&nu, &exact.nu, 2e-2, 5e-2);
        let y = eng.recover_y(&dict, &task);
        crate::testutil::assert_close(&y, &exact.y, 3e-2, 5e-2);
    }
}
