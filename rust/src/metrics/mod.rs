//! Evaluation metrics: PSNR (Fig. 5), ROC/AUC (Figs. 6–7, Tables III–IV),
//! and SNR learning curves (Fig. 4).

pub mod psnr;
pub mod roc;
pub mod snr;

pub use psnr::{mse, psnr};
pub use roc::{auc, roc_curve, RocPoint};
pub use snr::snr_db;
