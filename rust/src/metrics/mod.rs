//! Evaluation metrics: PSNR (Fig. 5), ROC/AUC (Figs. 6–7, Tables III–IV),
//! and SNR learning curves (Fig. 4).
//!
//! These are *quality* metrics over experiment outputs. Runtime
//! observability — named counters/gauges/histograms and virtual-clock
//! trace events — lives in [`crate::obs`] ([`crate::obs::MetricsRegistry`]).

pub mod psnr;
pub mod roc;
pub mod snr;

pub use psnr::{mse, psnr};
pub use roc::{auc, roc_curve, RocPoint};
pub use snr::snr_db;
