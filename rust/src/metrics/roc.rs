//! ROC curves and AUC for novelty detection (Figs. 6–7, Tables III–IV).
//!
//! Scores are novelty scores (higher ⇒ "declare novel"); labels mark the
//! ground-truth novel documents. Sweeping the threshold χ traces the ROC.

/// One operating point: probability of false alarm vs detection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RocPoint {
    pub pfa: f64,
    pub pd: f64,
    pub threshold: f64,
}

/// Full ROC curve from per-sample `(score, is_novel)` pairs, sorted by
/// descending threshold; includes the (0,0) and (1,1) endpoints.
pub fn roc_curve(scores: &[f64], labels: &[bool]) -> Vec<RocPoint> {
    assert_eq!(scores.len(), labels.len());
    let pos = labels.iter().filter(|&&l| l).count();
    let neg = labels.len() - pos;
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut curve = vec![RocPoint { pfa: 0.0, pd: 0.0, threshold: f64::INFINITY }];
    let (mut tp, mut fp) = (0usize, 0usize);
    let mut i = 0;
    while i < order.len() {
        // Process ties as one block so the curve is threshold-consistent.
        let t = scores[order[i]];
        while i < order.len() && scores[order[i]] == t {
            if labels[order[i]] {
                tp += 1;
            } else {
                fp += 1;
            }
            i += 1;
        }
        curve.push(RocPoint {
            pfa: if neg > 0 { fp as f64 / neg as f64 } else { 0.0 },
            pd: if pos > 0 { tp as f64 / pos as f64 } else { 0.0 },
            threshold: t,
        });
    }
    curve
}

/// Area under the ROC curve via the Mann–Whitney U statistic (ties count
/// half) — exact, no curve discretization error.
pub fn auc(scores: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let pos: Vec<f64> = scores
        .iter()
        .zip(labels)
        .filter_map(|(&s, &l)| l.then_some(s))
        .collect();
    let neg: Vec<f64> = scores
        .iter()
        .zip(labels)
        .filter_map(|(&s, &l)| (!l).then_some(s))
        .collect();
    if pos.is_empty() || neg.is_empty() {
        return f64::NAN;
    }
    let mut wins = 0.0f64;
    for &p in &pos {
        for &n in &neg {
            if p > n {
                wins += 1.0;
            } else if p == n {
                wins += 0.5;
            }
        }
    }
    wins / (pos.len() as f64 * neg.len() as f64)
}

/// Write an ROC curve to CSV (`pfa,pd,threshold`).
pub fn write_roc_csv(path: &std::path::Path, curve: &[RocPoint]) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "pfa,pd,threshold")?;
    for p in curve {
        writeln!(f, "{:.6},{:.6},{:.6e}", p.pfa, p.pd, p.threshold)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation_auc_one() {
        let scores = vec![0.9, 0.8, 0.2, 0.1];
        let labels = vec![true, true, false, false];
        assert_eq!(auc(&scores, &labels), 1.0);
    }

    #[test]
    fn inverted_separation_auc_zero() {
        let scores = vec![0.1, 0.2, 0.8, 0.9];
        let labels = vec![true, true, false, false];
        assert_eq!(auc(&scores, &labels), 0.0);
    }

    #[test]
    fn random_scores_auc_half() {
        let mut rng = crate::rng::Pcg64::new(1);
        let n = 4000;
        let scores: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.next_f64() < 0.3).collect();
        let a = auc(&scores, &labels);
        assert!((a - 0.5).abs() < 0.03, "auc {a}");
    }

    #[test]
    fn ties_count_half() {
        let scores = vec![0.5, 0.5];
        let labels = vec![true, false];
        assert_eq!(auc(&scores, &labels), 0.5);
    }

    #[test]
    fn degenerate_labels_nan() {
        assert!(auc(&[0.1, 0.2], &[true, true]).is_nan());
        assert!(auc(&[0.1, 0.2], &[false, false]).is_nan());
    }

    #[test]
    fn curve_monotone_and_bounded() {
        let mut rng = crate::rng::Pcg64::new(2);
        let scores: Vec<f64> = (0..200).map(|_| rng.next_f64()).collect();
        let labels: Vec<bool> = (0..200).map(|i| i % 3 == 0).collect();
        let c = roc_curve(&scores, &labels);
        assert_eq!(c[0].pfa, 0.0);
        assert_eq!(c[0].pd, 0.0);
        let last = c.last().unwrap();
        assert!((last.pfa - 1.0).abs() < 1e-12);
        assert!((last.pd - 1.0).abs() < 1e-12);
        for w in c.windows(2) {
            assert!(w[1].pfa >= w[0].pfa);
            assert!(w[1].pd >= w[0].pd);
            assert!(w[1].threshold <= w[0].threshold);
        }
    }

    /// Trapezoid integration of the curve must match the Mann–Whitney AUC.
    #[test]
    fn curve_area_matches_mann_whitney() {
        let mut rng = crate::rng::Pcg64::new(3);
        let scores: Vec<f64> = (0..500).map(|_| rng.next_f64()).collect();
        let labels: Vec<bool> = scores.iter().map(|&s| s + 0.3 * rng.next_f64() > 0.6).collect();
        let c = roc_curve(&scores, &labels);
        let mut area = 0.0;
        for w in c.windows(2) {
            area += (w[1].pfa - w[0].pfa) * 0.5 * (w[0].pd + w[1].pd);
        }
        let mw = auc(&scores, &labels);
        assert!((area - mw).abs() < 1e-9, "trapezoid {area} vs U {mw}");
    }
}
