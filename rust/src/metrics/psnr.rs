//! Peak signal-to-noise ratio (paper footnote 5):
//! `PSNR = 10·log10(I_max² / MSE)`.

/// Mean squared error between two images (flattened).
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// PSNR in dB with peak intensity `i_max` (the paper uses the maximum
/// pixel intensity of the image, 255 for 8-bit scenes).
pub fn psnr(reference: &[f32], test: &[f32], i_max: f32) -> f64 {
    let e = mse(reference, test);
    if e <= 0.0 {
        return f64::INFINITY;
    }
    10.0 * ((i_max as f64).powi(2) / e).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_images_infinite_psnr() {
        let img = vec![1.0, 2.0, 3.0];
        assert!(psnr(&img, &img, 255.0).is_infinite());
    }

    #[test]
    fn known_value() {
        // MSE = 4 → PSNR = 10 log10(255²/4) ≈ 42.11 dB.
        let a = vec![0.0f32; 10];
        let b = vec![2.0f32; 10];
        let p = psnr(&a, &b, 255.0);
        assert!((p - 42.1103).abs() < 1e-3, "{p}");
    }

    #[test]
    fn paper_noise_level_gives_14db() {
        // σ = 50 AWGN on a 255-peak image → PSNR = 10 log10(255²/2500) ≈ 14.15 dB,
        // matching the paper's reported 14.06 dB corrupted image.
        let p = 10.0 * (255.0f64 * 255.0 / 2500.0).log10();
        assert!((p - 14.15).abs() < 0.01);
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[1.0, 3.0], &[1.0, 1.0]), 2.0);
        assert_eq!(mse(&[], &[]), 0.0);
    }
}
