//! SNR measures for the Fig. 4 learning curves (§IV-A):
//! `SNR(i) = 10·log10(‖ref‖² / ‖est_i − ref‖²)`.

/// SNR of `est` against `reference`, in dB. Returns +∞ for an exact match
/// and −∞ for a zero reference with non-zero estimate.
pub fn snr_db(reference: &[f32], est: &[f32]) -> f64 {
    assert_eq!(reference.len(), est.len());
    let sig: f64 = reference.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let err: f64 = reference
        .iter()
        .zip(est)
        .map(|(&r, &e)| ((r - e) as f64).powi(2))
        .sum();
    if err == 0.0 {
        return f64::INFINITY;
    }
    if sig == 0.0 {
        return f64::NEG_INFINITY;
    }
    10.0 * (sig / err).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_match_infinite() {
        assert!(snr_db(&[1.0, 2.0], &[1.0, 2.0]).is_infinite());
    }

    #[test]
    fn known_value() {
        // ref = [1,0], est = [0.9,0]: SNR = 10 log10(1/0.01) = 20 dB.
        let s = snr_db(&[1.0, 0.0], &[0.9, 0.0]);
        assert!((s - 20.0).abs() < 1e-5, "{s}");
    }

    #[test]
    fn snr_improves_as_error_shrinks() {
        let reference = vec![1.0, -1.0, 0.5];
        let far: Vec<f32> = reference.iter().map(|v| v + 0.5).collect();
        let near: Vec<f32> = reference.iter().map(|v| v + 0.01).collect();
        assert!(snr_db(&reference, &near) > snr_db(&reference, &far));
    }
}
