//! Trace sinks: where emitted events go.
//!
//! The default is [`NullSink`] — via [`ObsHandle::null`] the entire
//! instrumentation layer reduces to one `Option::is_none` branch per
//! site, no allocation, no locking, no RNG, no clock access — so an
//! untraced run is bit-identical to a pre-observability build
//! (`tests/obs_parity.rs`). A recording run holds a ring-buffered
//! [`Recorder`] behind an `Arc<Mutex<..>>` so the threaded serve
//! pipeline's stages can share one sink.

use crate::obs::event::{ArgValue, EventKind, Track, TraceEvent};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// A destination for trace events. Implementations must not consume
/// randomness or touch any executor clock — the observer-effect
/// contract rests on sinks being pure accumulators.
pub trait TraceSink {
    /// Accept one event.
    fn record(&mut self, ev: TraceEvent);
    /// Whether this sink actually stores events (lets call sites skip
    /// argument construction entirely).
    fn enabled(&self) -> bool {
        true
    }
}

/// The zero-cost default sink: drops everything, reports disabled.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    #[inline]
    fn record(&mut self, _ev: TraceEvent) {}
    #[inline]
    fn enabled(&self) -> bool {
        false
    }
}

/// Ring-buffered recorder: keeps the most recent `cap` events and counts
/// what fell off the front, so a long run degrades to "latest window"
/// instead of unbounded memory.
#[derive(Debug)]
pub struct Recorder {
    buf: VecDeque<TraceEvent>,
    cap: usize,
    dropped: usize,
}

impl Recorder {
    /// Recorder keeping at most `cap` events (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> Self {
        Recorder { buf: VecDeque::new(), cap: cap.max(1), dropped: 0 }
    }

    /// Events currently held, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.buf.iter()
    }

    /// Number of events currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been recorded (or everything fell out).
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events evicted by the ring bound.
    pub fn dropped(&self) -> usize {
        self.dropped
    }
}

impl TraceSink for Recorder {
    fn record(&mut self, ev: TraceEvent) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }
}

/// Cloneable, thread-shareable handle the executors hold. `None` is the
/// null path: every emit helper is `#[inline]` and returns after one
/// branch, so the uninstrumented run pays a predictable-not-taken test
/// per site and nothing else.
#[derive(Clone, Default)]
pub struct ObsHandle(Option<Arc<Mutex<Recorder>>>);

impl ObsHandle {
    /// The disabled handle (the default for every executor).
    pub fn null() -> Self {
        ObsHandle(None)
    }

    /// A handle recording into a fresh ring buffer of `cap` events.
    pub fn recording(cap: usize) -> Self {
        ObsHandle(Some(Arc::new(Mutex::new(Recorder::new(cap)))))
    }

    /// Whether events are being kept. Sites with non-trivial arguments
    /// should guard on this before building them.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Record one event (no-op when disabled).
    #[inline]
    pub fn emit(&self, ev: TraceEvent) {
        if let Some(rec) = &self.0 {
            if let Ok(mut g) = rec.lock() {
                g.record(ev);
            }
        }
    }

    /// Span-begin shorthand.
    #[inline]
    pub fn span_begin(&self, t_us: u64, name: &'static str, track: Track) {
        if self.0.is_some() {
            self.emit(TraceEvent::new(t_us, EventKind::SpanBegin, name, track));
        }
    }

    /// Span-end shorthand.
    #[inline]
    pub fn span_end(&self, t_us: u64, name: &'static str, track: Track) {
        if self.0.is_some() {
            self.emit(TraceEvent::new(t_us, EventKind::SpanEnd, name, track));
        }
    }

    /// Instant shorthand (pass `Vec::new()` for no arguments).
    #[inline]
    pub fn instant(
        &self,
        t_us: u64,
        name: &'static str,
        track: Track,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        if self.0.is_some() {
            self.emit(TraceEvent { t_us, kind: EventKind::Instant, name, track, args });
        }
    }

    /// Counter-sample shorthand.
    #[inline]
    pub fn counter(&self, t_us: u64, name: &'static str, track: Track, value: f64) {
        if self.0.is_some() {
            self.emit(TraceEvent::new(t_us, EventKind::Counter(value), name, track));
        }
    }

    /// Copy of every event currently held (empty for the null handle).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        match &self.0 {
            Some(rec) => match rec.lock() {
                Ok(g) => g.events().cloned().collect(),
                Err(_) => Vec::new(),
            },
            None => Vec::new(),
        }
    }

    /// Events evicted by the ring bound (0 for the null handle).
    pub fn dropped(&self) -> usize {
        match &self.0 {
            Some(rec) => rec.lock().map(|g| g.dropped()).unwrap_or(0),
            None => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_sink_is_disabled_and_drops() {
        let mut s = NullSink;
        assert!(!TraceSink::enabled(&s));
        s.record(TraceEvent::new(0, EventKind::Instant, "x", Track::Run));
        let h = ObsHandle::null();
        assert!(!h.enabled());
        h.instant(1, "x", Track::Run, Vec::new());
        h.counter(2, "c", Track::Run, 1.0);
        assert!(h.snapshot().is_empty());
        assert_eq!(h.dropped(), 0);
    }

    #[test]
    fn recorder_ring_evicts_oldest() {
        let mut r = Recorder::new(3);
        for i in 0..5u64 {
            r.record(TraceEvent::new(i, EventKind::Instant, "x", Track::Run));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let ts: Vec<u64> = r.events().map(|e| e.t_us).collect();
        assert_eq!(ts, vec![2, 3, 4]);
        assert!(!r.is_empty());
    }

    #[test]
    fn handle_records_and_snapshots_in_order() {
        let h = ObsHandle::recording(16);
        assert!(h.enabled());
        h.span_begin(10, "adapt", Track::Agent(2));
        h.span_end(20, "adapt", Track::Agent(2));
        h.instant(20, "combine", Track::Agent(2), vec![("iter", ArgValue::U(1))]);
        let snap = h.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap[0].kind, EventKind::SpanBegin);
        assert_eq!(snap[1].kind, EventKind::SpanEnd);
        assert_eq!(snap[2].args, vec![("iter", ArgValue::U(1))]);
        // Clones share the same buffer (the threaded-pipeline pattern).
        let h2 = h.clone();
        h2.counter(30, "depth", Track::Run, 2.0);
        assert_eq!(h.snapshot().len(), 4);
    }
}
