//! Unified metrics registry: one named store for every counter, gauge,
//! and histogram the executors used to scatter across ad-hoc structs.
//!
//! [`crate::net::MessageStats`], [`crate::net::ChaosStats`], and the
//! async executor's gate-wait accounting remain the public, typed APIs —
//! they are now documented **views** over this registry: an executor's
//! [`crate::net::AsyncNetwork::metrics`] publishes its counters here
//! under stable names, and [`MetricsRegistry::message_stats`] /
//! [`MetricsRegistry::chaos_stats`] reconstruct the legacy structs
//! bit-for-bit (round-trip tested below), so downstream consumers can
//! migrate to names without a flag day.

use crate::math::stats;
use crate::net::{ChaosStats, MessageStats};
use std::collections::BTreeMap;

/// Named counters / gauges / histograms (BTreeMap-backed so iteration
/// and export order are deterministic).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Vec<f64>>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to the named counter (created at 0).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current counter value (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set the named gauge to its latest reading.
    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Latest gauge reading, if any.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Append one observation to the named histogram.
    pub fn observe(&mut self, name: &str, value: f64) {
        self.histograms.entry(name.to_string()).or_default().push(value);
    }

    /// Raw observations of the named histogram (empty when absent).
    pub fn histogram(&self, name: &str) -> &[f64] {
        self.histograms.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Percentile `p` of the named histogram via the shared exact-rank
    /// reader ([`crate::math::stats::percentile`]; 0.0 when absent).
    pub fn histogram_percentile(&self, name: &str, p: f64) -> f64 {
        stats::percentile(self.histogram(name), p)
    }

    /// Counter names in deterministic (lexicographic) order.
    pub fn counter_names(&self) -> impl Iterator<Item = &str> {
        self.counters.keys().map(String::as_str)
    }

    /// Gauge names in deterministic (lexicographic) order.
    pub fn gauge_names(&self) -> impl Iterator<Item = &str> {
        self.gauges.keys().map(String::as_str)
    }

    /// Absorb a [`MessageStats`] under `prefix` (`{prefix}.messages`,
    /// `{prefix}.bytes`, `{prefix}.rounds`).
    pub fn absorb_message_stats(&mut self, prefix: &str, s: &MessageStats) {
        self.inc(&format!("{prefix}.messages"), s.messages as u64);
        self.inc(&format!("{prefix}.bytes"), s.bytes as u64);
        self.inc(&format!("{prefix}.rounds"), s.rounds as u64);
    }

    /// Reconstruct the [`MessageStats`] view absorbed under `prefix`.
    pub fn message_stats(&self, prefix: &str) -> MessageStats {
        MessageStats {
            messages: self.counter(&format!("{prefix}.messages")) as usize,
            bytes: self.counter(&format!("{prefix}.bytes")) as usize,
            rounds: self.counter(&format!("{prefix}.rounds")) as usize,
        }
    }

    /// Absorb the chaos-layer degradation counters under `chaos.*`.
    pub fn absorb_chaos_stats(&mut self, s: &ChaosStats) {
        self.inc("chaos.dropped", s.dropped as u64);
        self.inc("chaos.retries", s.retries as u64);
        self.inc("chaos.abandoned", s.abandoned as u64);
        self.inc("chaos.crash_deferrals", s.crash_deferrals as u64);
        self.inc("chaos.forced_combines", s.forced_combines as u64);
        self.inc("chaos.stale_fallbacks", s.stale_fallbacks as u64);
        self.inc("chaos.excluded_neighbors", s.excluded_neighbors as u64);
        self.inc("chaos.max_fallback_staleness", s.max_fallback_staleness as u64);
        self.inc("chaos.corrupted", s.corrupted as u64);
    }

    /// Reconstruct the [`ChaosStats`] view absorbed by
    /// [`Self::absorb_chaos_stats`].
    pub fn chaos_stats(&self) -> ChaosStats {
        ChaosStats {
            dropped: self.counter("chaos.dropped") as usize,
            retries: self.counter("chaos.retries") as usize,
            abandoned: self.counter("chaos.abandoned") as usize,
            crash_deferrals: self.counter("chaos.crash_deferrals") as usize,
            forced_combines: self.counter("chaos.forced_combines") as usize,
            stale_fallbacks: self.counter("chaos.stale_fallbacks") as usize,
            excluded_neighbors: self.counter("chaos.excluded_neighbors") as usize,
            max_fallback_staleness: self.counter("chaos.max_fallback_staleness") as usize,
            corrupted: self.counter("chaos.corrupted") as usize,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms() {
        let mut r = MetricsRegistry::new();
        r.inc("a", 2);
        r.inc("a", 3);
        assert_eq!(r.counter("a"), 5);
        assert_eq!(r.counter("missing"), 0);
        r.set_gauge("g", 1.5);
        r.set_gauge("g", 2.5);
        assert_eq!(r.gauge("g"), Some(2.5));
        assert_eq!(r.gauge("none"), None);
        for v in [3.0, 1.0, 2.0] {
            r.observe("h", v);
        }
        assert_eq!(r.histogram("h"), &[3.0, 1.0, 2.0]);
        assert_eq!(r.histogram_percentile("h", 50.0), 2.0);
        assert_eq!(r.histogram_percentile("nope", 50.0), 0.0);
        let names: Vec<&str> = r.counter_names().collect();
        assert_eq!(names, vec!["a"], "deterministic order");
        assert_eq!(r.gauge_names().count(), 1);
    }

    /// The legacy structs round-trip through the registry bit-for-bit —
    /// they are views, not a second source of truth.
    #[test]
    fn message_and_chaos_stats_round_trip() {
        let ms = MessageStats { messages: 7, bytes: 4096, rounds: 3 };
        let cs = ChaosStats {
            dropped: 1,
            retries: 2,
            abandoned: 3,
            crash_deferrals: 4,
            forced_combines: 5,
            stale_fallbacks: 6,
            excluded_neighbors: 7,
            max_fallback_staleness: 8,
            corrupted: 9,
        };
        let mut r = MetricsRegistry::new();
        r.absorb_message_stats("net", &ms);
        r.absorb_chaos_stats(&cs);
        assert_eq!(r.message_stats("net"), ms);
        assert_eq!(r.chaos_stats(), cs);
        // An un-absorbed prefix reads as the zero struct.
        assert_eq!(r.message_stats("other"), MessageStats::default());
    }
}
