//! Typed trace events stamped with each executor's *virtual* clock.
//!
//! Every event carries a `t_us` stamp read from the clock the emitting
//! executor already maintains — the discrete-event sim clock in the
//! async/chaos executors, the [`crate::serve::control::ServiceModel`] /
//! [`crate::serve::control::PipeSim`] stage clocks in adaptive serving,
//! and the **iteration index** in the BSP executor (which has no time
//! axis at all). Tracing never advances any of these clocks and never
//! consumes randomness; it only *reads* state the run already computed
//! (the observer-effect contract, `tests/obs_parity.rs`).

/// Identity of the lane an event belongs to. The Chrome exporter maps
/// each variant to a (pid, tid) pair so Perfetto renders one row per
/// agent / edge / stage / controller.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Track {
    /// Per-agent lane (async/chaos executors).
    Agent(usize),
    /// Directed edge `from → to` (ψ send/delivery instants).
    Edge { from: usize, to: usize },
    /// Named lane: pipeline stages (`"form"`, `"infer"`, `"update"`) and
    /// fault windows (`"fault:partition"`, `"fault:crash"`, ...).
    Stage(&'static str),
    /// Named controller (`"batch"`, `"depth"`, `"tau"`).
    Controller(&'static str),
    /// Whole-run lane (round marks, run-level counters).
    Run,
}

/// One event argument value (the decision payload, staleness used, ...).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArgValue {
    U(u64),
    I(i64),
    F(f64),
    B(bool),
    S(&'static str),
}

/// Event kind, mirroring the Chrome `trace_event` phases the exporters
/// emit: span begin (`B`), span end (`E`), instant (`i`), counter (`C`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    SpanBegin,
    SpanEnd,
    Instant,
    Counter(f64),
}

/// One trace event. `&'static str` names keep the hot emit path free of
/// allocation (args allocate only when a site actually passes some, and
/// instrumentation sites guard on [`crate::obs::ObsHandle::enabled`]
/// before building them).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Virtual-clock stamp: sim-µs (async/chaos/serve) or iteration
    /// index (BSP). Per-executor semantics are in EXPERIMENTS.md
    /// §Observability.
    pub t_us: u64,
    pub kind: EventKind,
    pub name: &'static str,
    pub track: Track,
    pub args: Vec<(&'static str, ArgValue)>,
}

impl TraceEvent {
    /// Event with no arguments.
    pub fn new(t_us: u64, kind: EventKind, name: &'static str, track: Track) -> Self {
        TraceEvent { t_us, kind, name, track, args: Vec::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_event_has_no_args() {
        let ev = TraceEvent::new(5, EventKind::Instant, "x", Track::Agent(3));
        assert_eq!(ev.t_us, 5);
        assert!(ev.args.is_empty());
        assert_eq!(ev.track, Track::Agent(3));
    }

    #[test]
    fn counter_carries_its_value() {
        let ev = TraceEvent::new(0, EventKind::Counter(2.5), "queue_depth", Track::Run);
        match ev.kind {
            EventKind::Counter(v) => assert_eq!(v, 2.5),
            _ => panic!("expected counter"),
        }
    }
}
