//! Deterministic observability layer: virtual-clock tracing, a unified
//! metrics registry, and trace export shared by every executor.
//!
//! The layer has three pieces:
//!
//! * **Events + sinks** ([`event`], [`sink`]) — executors hold an
//!   [`ObsHandle`] (default: disabled) and emit typed span / instant /
//!   counter events stamped with the *virtual* clock they already
//!   maintain. The non-negotiable contract: tracing never perturbs the
//!   run — no RNG consumption, no clock advancement. A traced run is
//!   bit-identical to an untraced one on every executor
//!   (`tests/obs_parity.rs`).
//! * **Metrics** ([`registry`]) — [`MetricsRegistry`] is the single
//!   named store for counters/gauges/histograms; the legacy
//!   [`crate::net::MessageStats`] / [`crate::net::ChaosStats`] structs
//!   are round-trip views over it.
//! * **Export** ([`export`]) — JSONL and Perfetto-loadable Chrome
//!   `trace_event` writers plus the `ddl trace-check` validator, wired
//!   through `ddl <subcmd> --trace <path>` and the TOML `[obs]` block
//!   ([`crate::config::experiment::ObsConfig`]).
//!
//! Event-schema and per-executor clock semantics are documented in
//! EXPERIMENTS.md §Observability.

pub mod event;
pub mod export;
pub mod registry;
pub mod sink;

pub use event::{ArgValue, EventKind, Track, TraceEvent};
pub use export::{check_jsonl, write_chrome, write_jsonl, TraceCheck};
pub use registry::MetricsRegistry;
pub use sink::{NullSink, ObsHandle, Recorder, TraceSink};

use crate::config::experiment::ObsConfig;
use crate::error::{DdlError, Result};
use std::path::Path;

/// Build the handle an executor should record into: a ring-buffered
/// recorder when the config asks for tracing, the zero-cost null handle
/// otherwise.
pub fn handle_for(cfg: &ObsConfig) -> ObsHandle {
    if cfg.active() {
        ObsHandle::recording(cfg.ring_cap)
    } else {
        ObsHandle::null()
    }
}

/// Export the handle's events per the config. Returns `Ok(None)` when no
/// trace path is configured, `Ok(Some(n))` with the event count written
/// otherwise. Format `auto` picks JSONL for `.jsonl` paths and Chrome
/// for everything else.
pub fn export(cfg: &ObsConfig, handle: &ObsHandle) -> Result<Option<usize>> {
    let Some(path) = &cfg.trace_path else {
        return Ok(None);
    };
    let path = Path::new(path);
    let jsonl = match cfg.format.as_str() {
        "jsonl" => true,
        "chrome" => false,
        "auto" => path.extension().and_then(|e| e.to_str()) == Some("jsonl"),
        other => {
            return Err(DdlError::Config(format!(
                "obs.format: expected auto|jsonl|chrome, got '{other}'"
            )))
        }
    };
    let events = handle.snapshot();
    if jsonl {
        write_jsonl(path, &events)?;
    } else {
        write_chrome(path, &events)?;
    }
    Ok(Some(events.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handle_for_follows_config() {
        let mut cfg = ObsConfig::default();
        assert!(!handle_for(&cfg).enabled());
        cfg.enabled = true;
        assert!(handle_for(&cfg).enabled());
        cfg.enabled = false;
        cfg.trace_path = Some("x.jsonl".into());
        assert!(handle_for(&cfg).enabled(), "a trace path implies recording");
    }

    #[test]
    fn export_routes_by_format_and_extension() {
        let h = ObsHandle::recording(8);
        h.instant(1, "x", Track::Run, Vec::new());
        let dir = std::env::temp_dir();

        let mut cfg = ObsConfig::default();
        assert_eq!(export(&cfg, &h).unwrap(), None, "no path → no export");

        let jl = dir.join("ddl_obs_mod_test.jsonl");
        cfg.trace_path = Some(jl.to_string_lossy().into_owned());
        assert_eq!(export(&cfg, &h).unwrap(), Some(1));
        assert_eq!(check_jsonl(&jl).unwrap().events, 1);

        let ch = dir.join("ddl_obs_mod_test.json");
        cfg.trace_path = Some(ch.to_string_lossy().into_owned());
        assert_eq!(export(&cfg, &h).unwrap(), Some(1));
        let text = std::fs::read_to_string(&ch).unwrap();
        assert!(text.starts_with("{\"traceEvents\":["), "auto + .json → Chrome");

        cfg.format = "bogus".into();
        assert!(export(&cfg, &h).is_err(), "unknown format is a config error");
        std::fs::remove_file(&jl).ok();
        std::fs::remove_file(&ch).ok();
    }
}
