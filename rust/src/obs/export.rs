//! Trace exporters and the `ddl trace-check` validator.
//!
//! Both exporters serialize [`TraceEvent`]s in the Chrome `trace_event`
//! object shape (`name`/`ph`/`ts`/`pid`/`tid`/`args`):
//!
//! * **JSONL** — one event object per line; grep-able, streamable, and
//!   what [`check_jsonl`] validates in CI.
//! * **Chrome** — a `{"traceEvents": [...]}` document that loads
//!   directly in Perfetto (<https://ui.perfetto.dev>) or
//!   `chrome://tracing`, with `thread_name` metadata so agent / edge /
//!   stage / controller lanes are labeled.
//!
//! Track → (pid, tid) mapping: `Run` = (0, 0), `Agent(k)` = (1, k),
//! `Edge{from, ..}` = (2, from) with the destination in `args.to`,
//! `Stage(..)` = pid 3, `Controller(..)` = pid 4, with tids assigned by
//! first appearance (stable for a deterministic event stream).
//!
//! The `ts` stamps are the executors' *virtual* clocks; `trace-check`
//! deliberately does **not** require monotone `ts` — fault-window spans
//! are emitted up-front at schedule-build time with future stamps.

use crate::error::{DdlError, Result};
use crate::obs::event::{ArgValue, EventKind, Track, TraceEvent};
use std::fmt::Write as _;
use std::path::Path;

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        // JSON has no NaN/Inf literals; clamp to null-ish zero.
        "0".to_string()
    }
}

fn arg_json(v: &ArgValue) -> String {
    match v {
        ArgValue::U(u) => format!("{u}"),
        ArgValue::I(i) => format!("{i}"),
        ArgValue::F(f) => json_f64(*f),
        ArgValue::B(b) => format!("{b}"),
        ArgValue::S(s) => format!("\"{}\"", json_escape(s)),
    }
}

/// Lane-name bookkeeping: named tracks (stage / controller) get tids by
/// first appearance; the Chrome exporter also emits `thread_name`
/// metadata from the collected names.
#[derive(Default)]
struct Lanes {
    names: Vec<(&'static str, u64, u64)>, // (name, pid, tid)
}

impl Lanes {
    fn resolve(&mut self, track: &Track) -> (u64, u64) {
        match track {
            Track::Run => (0, 0),
            Track::Agent(k) => (1, *k as u64),
            Track::Edge { from, .. } => (2, *from as u64),
            Track::Stage(name) => self.named(3, name),
            Track::Controller(name) => self.named(4, name),
        }
    }

    fn named(&mut self, pid: u64, name: &'static str) -> (u64, u64) {
        if let Some((_, p, t)) = self.names.iter().find(|(n, p, _)| *n == name && *p == pid) {
            return (*p, *t);
        }
        let tid = self.names.iter().filter(|(_, p, _)| *p == pid).count() as u64;
        self.names.push((name, pid, tid));
        (pid, tid)
    }
}

/// One event as a Chrome `trace_event` JSON object (shared by both
/// exporters — one schema, two containers).
fn event_json(ev: &TraceEvent, lanes: &mut Lanes) -> String {
    let (pid, tid) = lanes.resolve(&ev.track);
    let (ph, extra) = match ev.kind {
        EventKind::SpanBegin => ("B", String::new()),
        EventKind::SpanEnd => ("E", String::new()),
        EventKind::Instant => ("i", ",\"s\":\"t\"".to_string()),
        EventKind::Counter(_) => ("C", String::new()),
    };
    let mut args = String::new();
    if let EventKind::Counter(v) = ev.kind {
        let _ = write!(args, "\"value\":{}", json_f64(v));
    }
    if let Track::Edge { to, .. } = ev.track {
        if !args.is_empty() {
            args.push(',');
        }
        let _ = write!(args, "\"to\":{to}");
    }
    for (k, v) in &ev.args {
        if !args.is_empty() {
            args.push(',');
        }
        let _ = write!(args, "\"{}\":{}", json_escape(k), arg_json(v));
    }
    format!(
        "{{\"name\":\"{}\",\"ph\":\"{}\",\"ts\":{},\"pid\":{},\"tid\":{}{},\"args\":{{{}}}}}",
        json_escape(ev.name),
        ph,
        ev.t_us,
        pid,
        tid,
        extra,
        args,
    )
}

fn write_file(path: &Path, contents: &str) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| DdlError::Runtime(format!("trace: mkdir {parent:?}: {e}")))?;
        }
    }
    std::fs::write(path, contents)
        .map_err(|e| DdlError::Runtime(format!("trace: write {path:?}: {e}")))
}

/// Write one event object per line (the `trace-check`-validated format).
pub fn write_jsonl(path: &Path, events: &[TraceEvent]) -> Result<()> {
    let mut lanes = Lanes::default();
    let mut out = String::new();
    for ev in events {
        out.push_str(&event_json(ev, &mut lanes));
        out.push('\n');
    }
    write_file(path, &out)
}

/// Write a Perfetto-loadable Chrome `trace_event` document, including
/// `process_name`/`thread_name` metadata for labeled lanes.
pub fn write_chrome(path: &Path, events: &[TraceEvent]) -> Result<()> {
    let mut lanes = Lanes::default();
    let mut body: Vec<String> = Vec::with_capacity(events.len() + 16);
    for ev in events {
        body.push(event_json(ev, &mut lanes));
    }
    for (pid, pname) in
        [(0u64, "run"), (1, "agents"), (2, "edges"), (3, "stages"), (4, "controllers")]
    {
        body.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"name\":\"{pname}\"}}}}"
        ));
    }
    for (name, pid, tid) in &lanes.names {
        body.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            json_escape(name)
        ));
    }
    let doc = format!("{{\"traceEvents\":[\n{}\n]}}\n", body.join(",\n"));
    write_file(path, &doc)
}

/// Summary returned by [`check_jsonl`] (the `ddl trace-check` payload).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCheck {
    pub events: usize,
    pub span_begins: usize,
    pub span_ends: usize,
    pub instants: usize,
    pub counters: usize,
}

/// Validate a JSONL event log against the event schema: every non-empty
/// line must parse as a JSON object with a string `name`, a `ph` in
/// `{B, E, i, C, M}`, and (for non-metadata events) numeric `ts`, `pid`,
/// `tid`, plus an `args` object. `ts` monotonicity is *not* required —
/// see the module docs.
pub fn check_jsonl(path: &Path) -> Result<TraceCheck> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| DdlError::Runtime(format!("trace-check: read {path:?}: {e}")))?;
    let mut sum = TraceCheck::default();
    for (idx, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let lineno = idx + 1;
        let bad = |what: &str| {
            DdlError::Runtime(format!("trace-check: line {lineno}: {what}"))
        };
        let v = crate::config::json::JsonValue::parse(line)
            .map_err(|e| bad(&format!("not valid JSON ({e})")))?;
        v.get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| bad("missing string field 'name'"))?;
        let ph = v
            .get("ph")
            .and_then(|p| p.as_str())
            .ok_or_else(|| bad("missing string field 'ph'"))?;
        match ph {
            "B" => sum.span_begins += 1,
            "E" => sum.span_ends += 1,
            "i" => sum.instants += 1,
            "C" => sum.counters += 1,
            "M" => {}
            other => return Err(bad(&format!("unknown phase '{other}'"))),
        }
        if ph != "M" {
            v.get("ts")
                .and_then(|t| t.as_f64())
                .ok_or_else(|| bad("missing numeric field 'ts'"))?;
            v.get("args")
                .and_then(|a| a.as_object())
                .ok_or_else(|| bad("missing object field 'args'"))?;
        }
        v.get("pid")
            .and_then(|p| p.as_f64())
            .ok_or_else(|| bad("missing numeric field 'pid'"))?;
        v.get("tid")
            .and_then(|t| t.as_f64())
            .ok_or_else(|| bad("missing numeric field 'tid'"))?;
        sum.events += 1;
    }
    Ok(sum)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        vec![
            TraceEvent::new(10, EventKind::SpanBegin, "adapt", Track::Agent(3)),
            TraceEvent::new(25, EventKind::SpanEnd, "adapt", Track::Agent(3)),
            TraceEvent {
                t_us: 25,
                kind: EventKind::Instant,
                name: "psi_send",
                track: Track::Edge { from: 3, to: 4 },
                args: vec![("iter", ArgValue::U(7)), ("dropped", ArgValue::B(false))],
            },
            TraceEvent::new(30, EventKind::Counter(5.0), "queue_depth", Track::Stage("form")),
            TraceEvent {
                t_us: 40,
                kind: EventKind::Instant,
                name: "tau_set",
                track: Track::Controller("tau"),
                args: vec![("tau", ArgValue::I(3)), ("drift", ArgValue::F(0.25))],
            },
        ]
    }

    #[test]
    fn jsonl_round_trips_through_check() {
        let dir = std::env::temp_dir();
        let path = dir.join("ddl_obs_export_test.jsonl");
        write_jsonl(&path, &sample_events()).unwrap();
        let sum = check_jsonl(&path).unwrap();
        assert_eq!(sum.events, 5);
        assert_eq!(sum.span_begins, 1);
        assert_eq!(sum.span_ends, 1);
        assert_eq!(sum.instants, 2);
        assert_eq!(sum.counters, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn chrome_document_parses_and_carries_metadata() {
        let dir = std::env::temp_dir();
        let path = dir.join("ddl_obs_export_test.json");
        write_chrome(&path, &sample_events()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::config::json::JsonValue::parse(&text).unwrap();
        let evs = doc.get("traceEvents").and_then(|e| e.as_array()).unwrap();
        // 5 events + 5 process_name + 2 thread_name (form, tau).
        assert_eq!(evs.len(), 12);
        let named: Vec<&str> =
            evs.iter().filter_map(|e| e.get("name").and_then(|n| n.as_str())).collect();
        assert!(named.contains(&"thread_name"));
        assert!(named.contains(&"psi_send"));
        // Edge destination travels in args.
        let psi = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("psi_send"))
            .unwrap();
        let args = psi.get("args").unwrap();
        assert_eq!(args.get("to").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(args.get("iter").and_then(|v| v.as_usize()), Some(7));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn check_rejects_malformed_lines() {
        let dir = std::env::temp_dir();
        let path = dir.join("ddl_obs_export_bad.jsonl");
        std::fs::write(&path, "{\"name\":\"x\",\"ph\":\"Z\",\"ts\":0,\"pid\":0,\"tid\":0,\"args\":{}}\n")
            .unwrap();
        assert!(check_jsonl(&path).is_err(), "unknown phase must fail");
        std::fs::write(&path, "not json\n").unwrap();
        assert!(check_jsonl(&path).is_err(), "non-JSON must fail");
        std::fs::write(&path, "{\"ph\":\"i\",\"ts\":0,\"pid\":0,\"tid\":0,\"args\":{}}\n").unwrap();
        assert!(check_jsonl(&path).is_err(), "missing name must fail");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn escaping_handles_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_f64(f64::NAN), "0");
        assert_eq!(arg_json(&ArgValue::S("q\"q")), "\"q\\\"q\"");
    }
}
