//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with median / MAD / percentile
//! reporting and a throughput helper, plus the cross-PR
//! [`regression_gate`] that compares a freshly-measured `BENCH_*.json`
//! against a committed baseline (`ddl bench-gate`, run by CI). Used by the
//! `rust/benches/*.rs` targets (declared with `harness = false`).

use crate::math::stats;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall times in seconds.
    pub samples: Vec<f64>,
    /// Optional work units per iteration (e.g. flops) for throughput.
    pub work_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples)
    }
    pub fn mad_s(&self) -> f64 {
        stats::mad(&self.samples)
    }
    pub fn p95_s(&self) -> f64 {
        stats::percentile(&self.samples, 95.0)
    }
    /// Work units per second at the median (e.g. GFLOP/s when work = flops).
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.median_s())
    }

    /// One-line human-readable report.
    pub fn report(&self) -> String {
        let med = self.median_s();
        let (scaled, unit) = scale_time(med);
        let mut line = format!(
            "{:<44} {:>9.3} {}  (mad {:.1}%, p95 {:.3} {}, n={})",
            self.name,
            scaled,
            unit,
            100.0 * self.mad_s() / med.max(1e-18),
            scale_time(self.p95_s()).0,
            scale_time(self.p95_s()).1,
            self.samples.len()
        );
        if let Some(tp) = self.throughput() {
            line.push_str(&format!("  [{:.2} Gunit/s]", tp / 1e9));
        }
        line
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn scale_time(s: f64) -> (f64, &'static str) {
    if s >= 1.0 {
        (s, "s ")
    } else if s >= 1e-3 {
        (s * 1e3, "ms")
    } else if s >= 1e-6 {
        (s * 1e6, "µs")
    } else {
        (s * 1e9, "ns")
    }
}

/// Benchmark runner with global time budget per case.
pub struct Bencher {
    /// Minimum measured iterations.
    pub min_iters: usize,
    /// Maximum measured iterations.
    pub max_iters: usize,
    /// Warmup iterations.
    pub warmup: usize,
    /// Soft time budget per case in seconds.
    pub budget_s: f64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { min_iters: 5, max_iters: 200, warmup: 2, budget_s: 2.0, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode bencher for CI-style runs.
    pub fn quick() -> Self {
        Bencher { min_iters: 3, max_iters: 30, warmup: 1, budget_s: 0.5, results: Vec::new() }
    }

    /// Time `f`, which must perform one full unit of work per call.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.bench_with_work(name, None, &mut f)
    }

    /// Time `f` and report throughput as `work` units per second.
    pub fn bench_work(&mut self, name: &str, work: f64, mut f: impl FnMut()) -> &BenchResult {
        self.bench_with_work(name, Some(work), &mut f)
    }

    fn bench_with_work(
        &mut self,
        name: &str,
        work: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < self.min_iters || start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult { name: name.to_string(), samples, work_per_iter: work };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// All accumulated results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write results as JSON: `{"results": [...], "derived": {...}}`.
    ///
    /// `derived` carries computed summary figures (speedup ratios etc.) so
    /// cross-PR tracking files like `BENCH_inference.json` are
    /// self-contained.
    pub fn write_json(
        &self,
        path: &std::path::Path,
        derived: &[(String, f64)],
    ) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{{")?;
        writeln!(f, "  \"results\": [")?;
        for (i, r) in self.results.iter().enumerate() {
            let tp = r
                .throughput()
                .map(|t| format!("{t:.3}"))
                .unwrap_or_else(|| "null".to_string());
            let comma = if i + 1 < self.results.len() { "," } else { "" };
            writeln!(
                f,
                "    {{\"name\": \"{}\", \"median_s\": {:.9}, \"mad_s\": {:.9}, \"p95_s\": {:.9}, \"samples\": {}, \"throughput_per_s\": {}}}{}",
                json_escape(&r.name),
                r.median_s(),
                r.mad_s(),
                r.p95_s(),
                r.samples.len(),
                tp,
                comma
            )?;
        }
        writeln!(f, "  ],")?;
        writeln!(f, "  \"derived\": {{")?;
        for (i, (k, v)) in derived.iter().enumerate() {
            let comma = if i + 1 < derived.len() { "," } else { "" };
            writeln!(f, "    \"{}\": {:.6}{}", json_escape(k), v, comma)?;
        }
        writeln!(f, "  }}")?;
        writeln!(f, "}}")?;
        Ok(())
    }

    /// Write a CSV of results (name, median_s, mad_s, p95_s, throughput).
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name,median_s,mad_s,p95_s,throughput_per_s")?;
        for r in &self.results {
            writeln!(
                f,
                "{},{:.9},{:.9},{:.9},{}",
                r.name,
                r.median_s(),
                r.mad_s(),
                r.p95_s(),
                r.throughput().map(|t| format!("{t:.3}")).unwrap_or_default()
            )?;
        }
        Ok(())
    }
}

/// Shared tail of every bench binary: print the derived figures, write
/// `results/bench_<name>.csv` and `BENCH_<name>.json`, and announce the
/// paths. Panics on IO failure, as the bench targets always did inline.
pub fn write_report(b: &Bencher, name: &str, derived: &[(String, f64)]) {
    println!("\nderived figures:");
    for (k, v) in derived {
        println!("  {k} = {v:.3}");
    }
    let csv = format!("results/bench_{name}.csv");
    let json = format!("BENCH_{name}.json");
    b.write_csv(std::path::Path::new(&csv)).unwrap();
    b.write_json(std::path::Path::new(&json), derived).unwrap();
    println!("\nwrote {csv} and {json}");
}

/// One derived-figure comparison produced by [`regression_gate`].
#[derive(Clone, Debug)]
pub struct GateRow {
    pub key: String,
    pub baseline: f64,
    pub current: f64,
    pub ok: bool,
}

/// Whether a derived key measures something where *smaller* is better
/// (latencies and other `_ms`/`_s`-suffixed times). Everything else —
/// speedups, throughputs — is higher-is-better. Public so the CLI table
/// can format the two kinds differently.
pub fn lower_is_better(key: &str) -> bool {
    key.contains("latency") || key.ends_with("_ms") || key.ends_with("_s")
}

/// Compare the `derived` figures (speedup ratios — machine-portable, unlike
/// raw wall times) of a current bench JSON against a committed baseline:
/// every baseline key must be present in the current file, at
/// `>= min_frac · baseline` for higher-is-better figures and at
/// `<= baseline / min_frac` for lower-is-better ones (latency keys; see
/// [`lower_is_better`]). Returns one row per baseline key, worst offenders
/// first; a missing key fails its row with `current = 0`.
pub fn regression_gate(
    current: &std::path::Path,
    baseline: &std::path::Path,
    min_frac: f64,
) -> crate::Result<Vec<GateRow>> {
    let cur = load_derived(current)?;
    let base = load_derived(baseline)?;
    if base.is_empty() {
        return Err(crate::DdlError::Config(format!(
            "bench-gate: baseline {} has no derived figures",
            baseline.display()
        )));
    }
    let mut rows: Vec<GateRow> = base
        .iter()
        .map(|(key, &b)| {
            let missing = !cur.contains_key(key);
            let c = cur.get(key).copied().unwrap_or(0.0);
            let ok = if missing {
                false
            } else if lower_is_better(key) {
                c <= b / min_frac.max(1e-12)
            } else {
                c >= min_frac * b
            };
            GateRow { key: key.clone(), baseline: b, current: c, ok }
        })
        .collect();
    // Worst offenders first: sort by the goodness ratio in the key's own
    // direction. `current == 0` only arises from a missing key (real
    // figures are strictly positive), which must rank worst regardless of
    // direction.
    rows.sort_by(|x, y| {
        let goodness = |r: &GateRow| {
            if r.current <= 0.0 {
                f64::NEG_INFINITY
            } else if lower_is_better(&r.key) {
                r.baseline / r.current
            } else {
                r.current / r.baseline.max(1e-12)
            }
        };
        goodness(x).partial_cmp(&goodness(y)).unwrap_or(std::cmp::Ordering::Equal)
    });
    Ok(rows)
}

fn load_derived(
    path: &std::path::Path,
) -> crate::Result<std::collections::BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        crate::DdlError::Config(format!("bench-gate: cannot read {}: {e}", path.display()))
    })?;
    let doc = crate::config::json::JsonValue::parse(&text)?;
    let derived = doc.get("derived").and_then(|d| d.as_object()).ok_or_else(|| {
        crate::DdlError::Config(format!("bench-gate: {} has no 'derived' object", path.display()))
    })?;
    Ok(derived
        .iter()
        .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut b = Bencher { min_iters: 4, max_iters: 8, warmup: 1, budget_s: 0.05, results: vec![] };
        let mut count = 0usize;
        b.bench("noop", || count += 1);
        let r = &b.results()[0];
        assert!(r.samples.len() >= 4);
        assert!(count >= r.samples.len()); // warmup + measured
        assert!(r.median_s() >= 0.0);
    }

    #[test]
    fn throughput_computed() {
        let mut b = Bencher::quick();
        b.bench_work("sleepless", 1e6, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(b.results()[0].throughput().unwrap() > 0.0);
    }

    #[test]
    fn json_written_and_parses() {
        let mut b = Bencher::quick();
        b.bench_work("unit \"quoted\"", 10.0, || {});
        let path = std::env::temp_dir().join("ddl_bench_test.json");
        b.write_json(&path, &[("speedup_x".to_string(), 5.25)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::config::json::JsonValue::parse(&text).unwrap();
        let results = doc.get("results").unwrap();
        match results {
            crate::config::json::JsonValue::Array(items) => {
                assert_eq!(items.len(), 1);
                assert_eq!(items[0].get("name").unwrap().as_str(), Some("unit \"quoted\""));
                assert!(items[0].get("median_s").unwrap().as_f64().is_some());
            }
            other => panic!("results not an array: {other:?}"),
        }
        let sp = doc.get("derived").unwrap().get("speedup_x").unwrap().as_f64().unwrap();
        assert!((sp - 5.25).abs() < 1e-9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn regression_gate_flags_regressions_and_missing_keys() {
        let dir = std::env::temp_dir();
        let base_p = dir.join("ddl_gate_base.json");
        let cur_p = dir.join("ddl_gate_cur.json");
        let mut base = Bencher::quick();
        base.bench("x", || {});
        base.write_json(
            &base_p,
            &[
                ("speedup_a".to_string(), 8.0),
                ("speedup_b".to_string(), 4.0),
                ("speedup_gone".to_string(), 2.0),
            ],
        )
        .unwrap();
        let mut cur = Bencher::quick();
        cur.bench("x", || {});
        // a holds (7.9 >= 0.5*8), b regressed (1.0 < 0.5*4), gone missing.
        cur.write_json(
            &cur_p,
            &[("speedup_a".to_string(), 7.9), ("speedup_b".to_string(), 1.0)],
        )
        .unwrap();
        let rows = regression_gate(&cur_p, &base_p, 0.5).unwrap();
        assert_eq!(rows.len(), 3);
        let row = |k: &str| rows.iter().find(|r| r.key == k).unwrap();
        assert!(row("speedup_a").ok);
        assert!(!row("speedup_b").ok);
        assert!(!row("speedup_gone").ok);
        assert_eq!(row("speedup_gone").current, 0.0);
        // Worst ratio sorts first.
        assert_eq!(rows[0].key, "speedup_gone");
        // Gate passes when everything holds.
        let rows = regression_gate(&cur_p, &cur_p, 0.9).unwrap();
        assert!(rows.iter().all(|r| r.ok));
        std::fs::remove_file(&base_p).ok();
        std::fs::remove_file(&cur_p).ok();
    }

    /// Latency-style keys gate in the opposite direction: an improvement
    /// (lower) must pass, a blow-up must fail.
    #[test]
    fn regression_gate_inverts_latency_keys() {
        let dir = std::env::temp_dir();
        let base_p = dir.join("ddl_gate_lat_base.json");
        let cur_p = dir.join("ddl_gate_lat_cur.json");
        let mut base = Bencher::quick();
        base.bench("x", || {});
        base.write_json(&base_p, &[("p99_latency_ms".to_string(), 40.0)]).unwrap();
        for (value, expect_ok) in [(12.0, true), (40.0, true), (79.0, true), (81.0, false)] {
            let mut cur = Bencher::quick();
            cur.bench("x", || {});
            cur.write_json(&cur_p, &[("p99_latency_ms".to_string(), value)]).unwrap();
            let rows = regression_gate(&cur_p, &base_p, 0.5).unwrap();
            assert_eq!(
                rows[0].ok, expect_ok,
                "latency {value} vs baseline 40 at min_frac 0.5"
            );
        }
        std::fs::remove_file(&base_p).ok();
        std::fs::remove_file(&cur_p).ok();
    }

    #[test]
    fn csv_written() {
        let mut b = Bencher::quick();
        b.bench("noop", || {});
        let path = std::env::temp_dir().join("ddl_bench_test.csv");
        b.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,"));
        assert!(text.contains("noop"));
        std::fs::remove_file(&path).ok();
    }
}
