//! Streaming inference service: batched serving with online adaptation.
//!
//! The paper's algorithm is inherently a streaming one — "each data sample
//! is presented to the network once" — and this module turns the batched
//! diffusion engine into a workload layer that serves such a stream:
//!
//! * [`queue`] — micro-batching admission queue: requests arrive on a
//!   microsecond clock and are released as minibatches by a
//!   max-size/max-wait policy ([`queue::BatchPolicy`]); [`SharedQueue`] is
//!   the thread-safe admission handle (admission never blocks while a
//!   batch is in flight);
//! * [`session`] — the serial service loop: a discrete-event single-server
//!   simulation whose service times are *measured* batched
//!   inference+update steps ([`crate::learn::OnlineTrainer::step`] over
//!   [`crate::infer::DiffusionEngine::run_batch`]), reporting throughput,
//!   latency percentiles, and ψ-traffic [`crate::net::MessageStats`];
//! * [`pipeline`] — the three-stage concurrent executor (`--pipeline`):
//!   batch formation, diffusion inference on persistent worker pools, and
//!   the Eq. 51 update overlap on separate threads with a double-buffered
//!   dictionary; a fixed bounded-staleness swap schedule makes the result
//!   **bit-identical** to its serial reference executor
//!   (`tests/serve_pipeline_parity.rs`).
//!
//! * [`control`] — the feedback control plane (`--adaptive`): a batch
//!   controller steering `(max_batch, max_wait_us)` to a p99-latency SLO
//!   on a sliding measurement window, a depth controller re-planning the
//!   pipeline depth at epoch boundaries, and the deterministic virtual
//!   service clock that makes every adaptive run replay bit-identically
//!   (`tests/control_adaptive.rs`).
//!
//! All three executors (serial, pipelined threaded, pipelined reference)
//! share a convergence-aware freeze/thaw loop
//! ([`crate::learn::ConvergenceDetector`], `[convergence]` / `--conv-*`):
//! once the dictionary drift stays below `tol` long enough the Eq. 51
//! update is frozen and its pipeline slot is released to pure inference;
//! a sustained loss jump (e.g. a distribution shift in a `--stream shift`
//! workload) thaws adaptation at a deterministic batch boundary. Every
//! freeze/thaw decision is a pure function of (config, batch index,
//! observed dictionaries), so sessions replay bit-identically
//! (`tests/convergence_freeze.rs`).
//!
//! Drive it with `ddl serve` (TOML sections `[serve]`/`[control]`, CLI
//! overrides) or programmatically via [`session::run_service`]; see
//! `examples/streaming_service.rs` and EXPERIMENTS.md §Serving/§Control.
//! For how the pipelined executor relates to the other diffusion
//! substrates (BSP, actors, async) and the bit-reproducibility contracts
//! they share, see the executor matrix in `ARCHITECTURE.md` at the
//! repository root.

pub mod control;
pub mod pipeline;
pub mod queue;
pub mod session;

pub use control::{
    clamped_policy, BatchController, ControlDecision, DepthController, DepthDecision, PipeSim,
    ServiceCalibrator, ServiceModel,
};
pub use pipeline::{run_pipelined, BatchFormer, PipelineExec};
pub use queue::{BatchPolicy, MicroBatchQueue, Request, SharedQueue};
pub use session::{
    generate_stream, run_service, run_service_with_dict, shift_boundaries, ServeReport,
};
