//! Streaming inference service: batched serving with online adaptation.
//!
//! The paper's algorithm is inherently a streaming one — "each data sample
//! is presented to the network once" — and this module turns the batched
//! diffusion engine into a workload layer that serves such a stream:
//!
//! * [`queue`] — micro-batching admission queue: requests arrive on a
//!   microsecond clock and are released as minibatches by a
//!   max-size/max-wait policy ([`queue::BatchPolicy`]);
//! * [`session`] — the service loop: a discrete-event single-server
//!   simulation whose service times are *measured* batched
//!   inference+update steps ([`crate::learn::OnlineTrainer::step`] over
//!   [`crate::infer::DiffusionEngine::run_batch`]), reporting throughput,
//!   latency percentiles, and ψ-traffic [`crate::net::MessageStats`].
//!
//! Drive it with `ddl serve` (TOML section `[serve]`, CLI overrides) or
//! programmatically via [`session::run_service`]; see
//! `examples/streaming_service.rs` and EXPERIMENTS.md §Serving.

pub mod queue;
pub mod session;

pub use queue::{BatchPolicy, MicroBatchQueue, Request};
pub use session::{generate_stream, run_service, ServeReport};
