//! Pipelined serving: overlap batch formation, diffusion inference, and
//! the Eq. 51 online update across threads.
//!
//! The serial session (`serve/session.rs`) is a single-server loop:
//! admission, the diffusion sweep, and the dictionary update run
//! back-to-back on one thread, so the engine's worker pool idles during
//! queueing and adaptation. This module restructures the session into a
//! three-stage concurrent pipeline:
//!
//! 1. **Formation** (main thread) — the [`BatchFormer`] replays the arrival
//!    stream through the micro-batching policy on the virtual clock and
//!    forms batch `i+1` while batch `i` computes. Formation never consults
//!    service times, so batch composition is a pure function of the stream
//!    and the policy — the determinism anchor of the whole pipeline.
//! 2. **Inference** (worker threads + persistent pools) — up to
//!    `pipeline_depth` batches are in flight, each a
//!    [`DiffusionEngine::run_batch`] sweep against an immutable dictionary
//!    *snapshot* on a long-lived [`crate::net::PersistentPool`].
//! 3. **Update** (dedicated updater thread) — primal recovery, statistics,
//!    and the Eq. 51 update ([`crate::learn::recover_and_stats`] /
//!    [`crate::learn::apply_eq51_update`]) run against the **write** side of
//!    a [`DictDoubleBuffer`] while inference reads published snapshots —
//!    inference never blocks on the update.
//!
//! ## The fixed swap schedule (bounded staleness)
//!
//! Let `D_j` be the dictionary after the updates of batches `0..j`
//! (`D_0` = initial). With pipeline depth `D`, batch `j` is inferred
//! against the snapshot `S_j = D_{max(0, j − D)}`: updates lag inference by
//! exactly the pipeline depth, never "whatever happened to be published"
//! — the schedule is data-independent, so the final dictionary, per-batch
//! losses, and ψ-traffic are **bit-identical** for the threaded executor
//! and the serial reference executor ([`PipelineExec::Reference`]), at any
//! thread count and depth. The speedup is pure overlap, not a silently
//! different algorithm. This is the scheme D4L (Koppel et al. 2016) and
//! Daneshmand et al. (2016) use to overlap local optimization with
//! communication, made deterministic.
//!
//! Depth 1 is the classic three-stage pipeline (update of batch `i−1`
//! overlaps inference of batch `i`); depth ≥ 2 additionally overlaps
//! consecutive inference sweeps (batch `i+1` depends on `U_{i−1}`, not on
//! batch `i`), which is where the throughput multiplier comes from when
//! cores outnumber the engine's thread count.
//!
//! Wall-clock metrics (throughput, latency percentiles) are measured on
//! the real clock and naturally differ between executors; the parity
//! contract covers dictionaries, sample/batch counts, losses, and
//! [`MessageStats`].
//!
//! ## Adaptive mode (`--adaptive`, `[control] enabled = true`)
//!
//! The control plane ([`crate::serve::control`]) rides on the snapshot
//! schedule itself: every dictionary snapshot travels as a `Token` that
//! may also carry a fresh [`BatchPolicy`] decided by the
//! [`BatchController`], applied by the formation stage *before* forming
//! the batch that consumes the token — so policy swaps land at
//! deterministic points of the batch sequence in both executors. The
//! [`DepthController`] re-plans the depth by ±1 at batch-epoch
//! boundaries, realized by the updater emitting two tokens (deepen) or
//! withholding one (shallow) — the schedule generalizes to
//! `S_j = D_{max(0, j − d_j)}` with `d_j` the token count in flight, and
//! stays bit-identical between the threaded and reference executors.
//! Latency/throughput figures come from the deterministic virtual stage
//! clock ([`PipeSim`]) instead of wall time, so adaptive runs replay
//! bit-identically; with the control plane disabled this module takes
//! exactly its static PR 3 code paths.
//!
//! ## Fault injection (`[serve] kill_slot` / `kill_at_batch` / `queue_capacity`)
//!
//! Two deterministic serving faults ride the same machinery the chaos
//! layer uses for the async executor: **worker death mid-batch** — the
//! victim slot discards the first Work with batch index ≥ `kill_at_batch`
//! and exits; the dispatcher, which knows the same config, clones the
//! batch before the fatal send and re-dispatches it to the next live slot
//! (traced as `worker_death` / `batch_redispatch`), so the updater sees
//! every batch exactly once and the final dictionary stays bit-identical
//! to the no-fault reference executor — and **bounded admission** —
//! `queue_capacity` > 0 sheds overflow arrivals with the typed
//! [`DdlError::QueueFull`] rejection (traced as `queue_shed`, surfaced to
//! the batch controller as overload pressure).

use crate::config::experiment::ServeConfig;
use crate::error::{DdlError, Result};
use crate::infer::{DiffusionEngine, NuView};
use crate::learn::{apply_eq51_update, recover_and_stats, ConvEvent, ConvergenceDetector};
use crate::math::stats;
use crate::model::{DictDoubleBuffer, DistributedDictionary, TaskSpec};
use crate::net::{MessageStats, PersistentPool};
use crate::obs::{ArgValue, ObsHandle, Track};
use crate::ops::prox::DictProx;
use crate::serve::control::{
    clamped_policy, BatchController, ControlDecision, DepthController, DepthDecision, PipeSim,
    ServiceModel,
};
use crate::serve::queue::{screen_batch, BatchPolicy, Request, SharedQueue};
use crate::serve::session::{
    build_engine, emit_conv_events, loss_quarters, serve_params, serve_task, setup,
    slo_violation_frac, ServeReport, SessionSetup,
};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Which executor runs the pipeline schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineExec {
    /// Three-stage concurrent executor (production path).
    Threaded,
    /// Single-threaded reference executor of the identical schedule — the
    /// comparator for the bitwise parity tests.
    Reference,
}

/// Service-independent batch formation: replays the arrival stream through
/// the micro-batching policy on the virtual clock, jumping only to arrival
/// and deadline events. Unlike the serial session's single-server loop,
/// the clock never advances by service time — admission and formation are
/// decoupled from inference, so batch `i+1` forms while batch `i` is in
/// flight and the batch sequence is a deterministic function of
/// `(stream, policy)` alone.
///
/// Admission goes through a [`SharedQueue`]; [`Self::queue`] exposes the
/// handle so external producers can inject requests concurrently in a real
/// deployment (the replayed-stream sessions used for parity and benches
/// are single-producer).
pub struct BatchFormer {
    queue: Arc<SharedQueue>,
    stream: VecDeque<(u64, Vec<f32>)>,
    now_us: u64,
    /// Queue sheds already handed out via [`Self::take_shed`].
    reported_shed: u64,
    /// Poisoned-sample screen threshold (`None` = screen off): formed
    /// batches are filtered through
    /// [`crate::serve::queue::screen_batch`] before release, so screening
    /// stays part of the deterministic formation stage and both executors
    /// quarantine identically.
    screen: Option<f64>,
    /// Samples quarantined by the screen since construction.
    quarantined: u64,
    /// Quarantines already handed out via [`Self::take_quarantined`].
    reported_quarantined: u64,
}

impl BatchFormer {
    /// Former over `stream` (`(arrival_us, x)` pairs in arrival order)
    /// with unbounded admission.
    pub fn new(policy: BatchPolicy, stream: Vec<(u64, Vec<f32>)>) -> Self {
        Self::with_capacity(policy, 0, stream)
    }

    /// Former with a bounded admission queue (`capacity` requests, `0` =
    /// unbounded): arrivals that find the queue full are shed — counted
    /// by the queue and surfaced batch-by-batch via [`Self::take_shed`].
    pub fn with_capacity(
        policy: BatchPolicy,
        capacity: usize,
        stream: Vec<(u64, Vec<f32>)>,
    ) -> Self {
        BatchFormer {
            queue: Arc::new(SharedQueue::with_capacity(policy, capacity)),
            stream: stream.into(),
            now_us: 0,
            reported_shed: 0,
            screen: None,
            quarantined: 0,
            reported_quarantined: 0,
        }
    }

    /// Arm (or disarm) the poisoned-sample norm screen.
    pub fn with_screen(mut self, threshold: Option<f64>) -> Self {
        self.screen = threshold;
        self
    }

    /// Quarantines recorded by the screen since the last call. Travels
    /// with the next formed batch like [`Self::take_shed`], so the updater
    /// traces and the controller observe them at a deterministic point of
    /// the batch sequence.
    pub fn take_quarantined(&mut self) -> usize {
        let delta = self.quarantined - self.reported_quarantined;
        self.reported_quarantined = self.quarantined;
        delta as usize
    }

    /// Total samples quarantined by the screen.
    pub fn quarantined_total(&self) -> u64 {
        self.quarantined
    }

    /// Sheds recorded by the bounded queue since the last call (always 0
    /// for unbounded queues). Travels with the next formed batch so the
    /// updater-side controller sees overflow at a deterministic point of
    /// the batch sequence.
    pub fn take_shed(&mut self) -> usize {
        let total = self.queue.shed_count();
        let delta = total - self.reported_shed;
        self.reported_shed = total;
        delta as usize
    }

    /// The shared admission queue.
    pub fn queue(&self) -> Arc<SharedQueue> {
        Arc::clone(&self.queue)
    }

    /// Current virtual-clock reading (µs).
    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Form the next batch, or `None` when the stream is exhausted and the
    /// queue drained. Partial batches release at the max-wait deadline;
    /// end-of-stream flushes the remainder immediately (nothing else will
    /// arrive), exactly like the serial session.
    pub fn next_batch(&mut self) -> Option<Vec<Request>> {
        loop {
            // Admit every request that has arrived by the current clock.
            // A bounded queue sheds the overflow (the queue counts it;
            // `take_shed` reports it with the next formed batch).
            while self.stream.front().is_some_and(|(t, _)| *t <= self.now_us) {
                if let Some((t, x)) = self.stream.pop_front() {
                    let _ = self.queue.try_push(x, t);
                }
            }
            if self.queue.ready(self.now_us) {
                let batch = self.queue.drain_batch();
                return Some(self.apply_screen(batch));
            }
            match self.stream.front() {
                None => {
                    if self.queue.is_empty() {
                        return None;
                    }
                    let batch = self.queue.drain_batch();
                    return Some(self.apply_screen(batch));
                }
                Some(&(t_arrival, _)) => {
                    // Idle: jump to the next arrival or batch deadline.
                    let mut t_next = t_arrival;
                    if let Some(d) = self.queue.next_deadline_us() {
                        t_next = t_next.min(d);
                    }
                    self.now_us = self.now_us.max(t_next);
                }
            }
        }
    }

    /// Filter one formed batch through the norm screen (identity with the
    /// screen off). The min-norm sample always survives, so a released
    /// batch is never empty.
    fn apply_screen(&mut self, batch: Vec<Request>) -> Vec<Request> {
        match self.screen {
            Some(threshold) => {
                let (kept, dropped) = screen_batch(batch, threshold);
                self.quarantined += dropped.len() as u64;
                kept
            }
            None => batch,
        }
    }
}

/// One circulating pipeline permit: the dictionary snapshot the consuming
/// batch infers against, optionally piggybacking a fresh batch policy
/// from the controller (applied by the formation stage before the
/// consuming batch is formed — the deterministic policy-swap point).
pub(crate) struct Token {
    snap: DistributedDictionary,
    policy: Option<BatchPolicy>,
}

/// Adaptive-mode controller bundle owned by the updater (stage 3 sees
/// every completed batch in order, so it is the one deterministic place
/// feedback can close).
struct PipeCtl {
    batch: BatchController,
    depth: DepthController,
    sim: PipeSim,
    /// A decision not yet shipped on a token (made while a token was
    /// withheld); attached to the next emission.
    pending_policy: Option<BatchPolicy>,
}

/// Stage-3 state: the double-buffered dictionary plus every deterministic
/// accumulator of the session (losses, traffic, served counts). Both
/// executors drive batches through [`Self::process`] in batch order, which
/// is what makes them bit-identical.
struct UpdaterState {
    dict: DictDoubleBuffer,
    task: TaskSpec,
    prox: DictProx,
    mu_w: f32,
    m: usize,
    iters: usize,
    directed_edges: usize,
    ys: Vec<f32>,
    corr: Vec<f32>,
    mean: Vec<f32>,
    batch_losses: Vec<f64>,
    stats: MessageStats,
    served: usize,
    /// Per-request latency: inference completion (the moment the result
    /// is servable; the Eq. 51 update continues in the background) minus
    /// the request's virtual arrival offset, clamped at 0. Static mode
    /// stamps completion on the wall clock (ms since session start);
    /// adaptive mode uses the deterministic virtual stage clock.
    latencies_ms: Vec<f64>,
    /// Control plane (adaptive mode only).
    ctl: Option<PipeCtl>,
    /// Convergence detector ([`crate::learn::convergence`]): decides at
    /// each batch boundary whether the *next* batch skips the Eq. 51
    /// update. Stage 3 sees every batch in order in both executors, so
    /// freeze/thaw points are identical for the threaded and reference
    /// schedules. Inert (`tol = 0`) by default.
    detector: ConvergenceDetector,
    /// Trace sink (clones share one ring buffer, so the threaded
    /// executor's updater thread and the formation thread write into the
    /// same recorder). Stage spans are stamped on the virtual stage clock
    /// ([`PipeSim`]) in adaptive mode and on the formation clock
    /// otherwise — never the wall clock, so tracing cannot perturb the
    /// run.
    obs: ObsHandle,
}

/// Everything a finished session hands back to [`run_pipelined`].
struct SessionAccum {
    dict: DistributedDictionary,
    batch_losses: Vec<f64>,
    stats: MessageStats,
    served: usize,
    latencies_ms: Vec<f64>,
    decisions: Vec<ControlDecision>,
    depth_trace: Vec<DepthDecision>,
    conv_events: Vec<ConvEvent>,
    frozen_batches: usize,
    /// Virtual session duration (adaptive mode; `None` = use wall clock).
    virtual_duration_us: Option<u64>,
}

impl UpdaterState {
    fn new(
        cfg: &ServeConfig,
        dict0: DistributedDictionary,
        directed_edges: usize,
        init_depth: usize,
        slots: usize,
    ) -> Self {
        let ctl = cfg.control.enabled.then(|| PipeCtl {
            batch: BatchController::new(&cfg.control, cfg.batch, cfg.max_wait_us),
            depth: DepthController::new(&cfg.control, init_depth),
            sim: PipeSim::new(ServiceModel::from_config(&cfg.control), slots, init_depth),
            pending_policy: None,
        });
        UpdaterState {
            dict: DictDoubleBuffer::new(dict0),
            task: serve_task(cfg),
            prox: DictProx::None,
            mu_w: cfg.mu_w,
            m: cfg.dim,
            iters: cfg.infer.iters,
            directed_edges,
            ys: Vec::new(),
            corr: Vec::new(),
            mean: Vec::new(),
            batch_losses: Vec::new(),
            stats: MessageStats::default(),
            served: 0,
            latencies_ms: Vec::new(),
            ctl,
            detector: ConvergenceDetector::new(cfg.convergence.clone()),
            obs: ObsHandle::null(),
        }
    }

    /// A fresh copy of the latest published snapshot (pipeline prefill).
    fn fresh_snapshot(&self) -> DistributedDictionary {
        self.dict.read().clone()
    }

    /// Process batch `j`'s inference result: recovery + stats against the
    /// snapshot `S_j` the batch was inferred with, publish the
    /// authoritative pre-update state (recycling the `S_j` buffer)
    /// through `emit`, then apply the Eq. 51 update to the write buffer.
    /// `emit` fires before the update so a depth-1 pipeline genuinely
    /// overlaps `U_j` with the next batch's inference.
    ///
    /// In adaptive mode this is also where the whole control plane turns:
    /// the virtual stage clock advances, latencies are stamped against
    /// it, the batch controller may mint a policy (shipped on the emitted
    /// token), and the depth controller may emit two tokens or none at an
    /// epoch boundary (depth ±1).
    fn process(
        &mut self,
        mut snap: DistributedDictionary,
        batch: &[Request],
        view: &NuView<'_>,
        stamp_ms: f64,
        formed: Formed,
        mut emit: impl FnMut(Token),
    ) -> Result<()> {
        let j = self.batch_losses.len();
        // Convergence freeze: decided at the previous batch boundary, so
        // the verdict is already fixed when this batch's work begins —
        // identical in the threaded and reference executors.
        let frozen = self.detector.is_frozen();
        if formed.shed > 0 && self.obs.enabled() {
            self.obs.instant(
                formed.at_us,
                "queue_shed",
                Track::Stage("form"),
                vec![("j", ArgValue::U(j as u64)), ("count", ArgValue::U(formed.shed as u64))],
            );
        }
        if formed.quarantined > 0 && self.obs.enabled() {
            self.obs.instant(
                formed.at_us,
                "sample_quarantined",
                Track::Stage("form"),
                vec![
                    ("j", ArgValue::U(j as u64)),
                    ("count", ArgValue::U(formed.quarantined as u64)),
                ],
            );
        }
        let refs: Vec<&[f32]> = batch.iter().map(|r| r.x.as_slice()).collect();
        let tstats = recover_and_stats(
            &snap,
            &self.task,
            &refs,
            view,
            &mut self.ys,
            &mut self.corr,
            &mut self.mean,
        )?;
        self.batch_losses.push(tstats.mean_loss);
        self.served += batch.len();
        let mut emit_count = 1usize;
        // Stamp for convergence instants: the batch's virtual completion
        // in adaptive mode, the formation clock otherwise.
        let mut conv_stamp_us = formed.at_us;
        if let Some(ctl) = self.ctl.as_mut() {
            // Virtual stage clock: inference completion on the model,
            // never the wall clock (the replay anchor). A frozen batch
            // charges no update time — the update stage is released to
            // pure inference.
            ctl.sim.set_frozen(frozen);
            let (done_us, starved) = ctl.sim.batch(j, formed.at_us, batch.len());
            conv_stamp_us = done_us;
            if self.obs.enabled() {
                self.obs.instant(
                    formed.at_us,
                    "batch_form",
                    Track::Stage("form"),
                    vec![
                        ("j", ArgValue::U(j as u64)),
                        ("size", ArgValue::U(batch.len() as u64)),
                    ],
                );
                self.obs.span_begin(formed.at_us, "infer", Track::Stage("infer"));
                self.obs.span_end(done_us, "infer", Track::Stage("infer"));
                self.obs.instant(
                    done_us,
                    "update",
                    Track::Stage("update"),
                    vec![("j", ArgValue::U(j as u64)), ("starved", ArgValue::B(starved))],
                );
            }
            let from = self.latencies_ms.len();
            for r in batch {
                self.latencies_ms
                    .push(done_us.saturating_sub(r.arrival_us) as f64 / 1e3);
            }
            // Load the bounded queue shed before this batch formed is
            // the controller's overload signal
            // ([`BatchController::observe_shed`]); quarantined samples
            // ride the same path — load the service refused to process.
            ctl.batch.observe_shed(formed.shed + formed.quarantined);
            ctl.batch.observe_batch(batch.len(), formed.cap, &self.latencies_ms[from..]);
            if let Some(policy) = ctl.batch.maybe_decide(done_us) {
                // PR 5's `ServeReport::decisions` row, as a trace instant.
                if self.obs.enabled() {
                    self.obs.instant(
                        done_us,
                        "batch_policy",
                        Track::Controller("batch"),
                        vec![
                            ("max_batch", ArgValue::U(policy.max_batch as u64)),
                            ("max_wait_us", ArgValue::U(policy.max_wait_us)),
                        ],
                    );
                }
                ctl.pending_policy = Some(policy);
            }
            ctl.depth.observe(starved);
            let delta = ctl.depth.maybe_replan(j);
            if delta != 0 && self.obs.enabled() {
                // PR 5's `ServeReport::depth_trace` row, as a trace instant.
                self.obs.instant(
                    done_us,
                    "depth_replan",
                    Track::Controller("depth"),
                    vec![("j", ArgValue::U(j as u64)), ("delta", ArgValue::I(delta as i64))],
                );
            }
            emit_count = (1i32 + delta) as usize;
            ctl.sim.emit_tokens(emit_count);
        } else {
            if self.obs.enabled() {
                // Static mode has no virtual service clock; only the
                // formation-clock instant is traced (wall-clock stage
                // timings would not replay).
                self.obs.instant(
                    formed.at_us,
                    "batch_form",
                    Track::Stage("form"),
                    vec![
                        ("j", ArgValue::U(j as u64)),
                        ("size", ArgValue::U(batch.len() as u64)),
                    ],
                );
            }
            for r in batch {
                // Completion − arrival, like the serial executor. The
                // pipeline replays virtual arrivals at full speed, so a
                // request can complete before its arrival offset would
                // have elapsed in real time — clamp to 0 (the pipeline
                // outran the arrival process).
                self.latencies_ms.push((stamp_ms - r.arrival_us as f64 / 1e3).max(0.0));
            }
        }
        // ψ traffic, accounted exactly as the serial session does: one
        // message per directed edge per diffusion iteration carrying the
        // whole minibatch (see `serve/session.rs`).
        self.stats.record_exchange(self.directed_edges * self.iters, batch.len() * self.m);
        self.stats.add_rounds(self.iters);

        // Publish the authoritative pre-update state D_j: swap the double
        // buffer and recycle the S_j buffer into the next token. An
        // epoch-boundary depth change emits two tokens (both D_j — the
        // second is a fresh clone) or none (the S_j buffer is dropped).
        self.dict.publish();
        let policy = if emit_count > 0 {
            self.ctl.as_mut().and_then(|c| c.pending_policy.take())
        } else {
            None
        };
        match emit_count {
            0 => {}
            1 => {
                snap.copy_from(self.dict.read())?;
                emit(Token { snap, policy });
            }
            2 => {
                snap.copy_from(self.dict.read())?;
                emit(Token { snap, policy });
                emit(Token { snap: self.fresh_snapshot(), policy: None });
            }
            _ => unreachable!("depth moves by at most one per epoch"),
        }

        // Eq. 51 into the write buffer: D_j → D_{j+1}. Inference of later
        // batches reads published snapshots, never this buffer. A frozen
        // batch skips exactly this write (D_{j+1} = D_j); the publish and
        // token traffic above are untouched, so the swap schedule — and
        // with it threaded ≡ reference parity — is identical either way.
        if !frozen {
            apply_eq51_update(
                self.dict.write_mut(),
                &self.task,
                self.prox,
                self.mu_w,
                &self.ys,
                view,
            );
        }
        // Feed the detector the post-batch dictionary and loss; mirror any
        // freeze/thaw/drift decisions onto the trace.
        let events = self.detector.observe(j, self.dict.write_mut(), tstats.mean_loss);
        emit_conv_events(&self.obs, conv_stamp_us, events);
        Ok(())
    }

    fn into_parts(self) -> SessionAccum {
        let (decisions, depth_trace, virtual_duration_us) = match self.ctl {
            Some(ctl) => (
                ctl.batch.into_decisions(),
                ctl.depth.into_decisions(),
                Some(ctl.sim.now_us()),
            ),
            None => (Vec::new(), Vec::new(), None),
        };
        let frozen_batches = self.detector.frozen_batches();
        SessionAccum {
            dict: self.dict.into_write(),
            batch_losses: self.batch_losses,
            stats: self.stats,
            served: self.served,
            latencies_ms: self.latencies_ms,
            decisions,
            depth_trace,
            conv_events: self.detector.into_events(),
            frozen_batches,
            virtual_duration_us,
        }
    }
}

/// Formation-side facts that travel with a batch to the updater: the
/// virtual formation-clock reading and the `max_batch` cap the batch was
/// formed under (a fresh policy only reaches the queue when its token is
/// consumed, so in-flight batches may predate the current policy).
#[derive(Clone, Copy)]
struct Formed {
    at_us: u64,
    cap: usize,
    /// Requests the bounded admission queue shed since the previous
    /// batch formed (0 for unbounded queues).
    shed: usize,
    /// Samples the poison screen quarantined since the previous batch
    /// formed (0 with the screen off).
    quarantined: usize,
}

/// Dispatch of one formed batch to an inference worker.
struct Work {
    j: usize,
    snap: DistributedDictionary,
    batch: Vec<Request>,
    formed: Formed,
}

/// One completed inference: the shipped dual iterates plus everything the
/// updater needs (the snapshot travels back for recovery and recycling).
struct Done {
    j: usize,
    snap: DistributedDictionary,
    batch: Vec<Request>,
    v: Vec<f32>,
    b: usize,
    stamp_ms: f64,
    formed: Formed,
}

/// Run the pipelined session. Returns the report and the final adapted
/// dictionary (for bitwise parity checks).
pub fn run_pipelined(
    cfg: &ServeConfig,
    exec: PipelineExec,
    log: &mut dyn FnMut(&str),
) -> Result<(ServeReport, DistributedDictionary)> {
    let adaptive = cfg.control.enabled;
    // Initial depth: static value, clamped into the controller's bounds
    // when it is in charge (DepthController::new applies the identical
    // clamp — the prefilled token count and the controller must agree).
    let depth = if adaptive {
        let lo = cfg.control.depth_min.max(1);
        cfg.pipeline_depth.max(1).clamp(lo, cfg.control.depth_max.max(lo))
    } else {
        cfg.pipeline_depth.max(1)
    };
    let SessionSetup { graph, topo, dict0, stream, screen } = setup(cfg)?;
    let directed_edges = 2 * graph.edge_count();
    let policy = if adaptive {
        clamped_policy(&cfg.control, cfg.batch, cfg.max_wait_us)
    } else {
        BatchPolicy::new(cfg.batch, cfg.max_wait_us)
    };
    let task_threads = cfg.infer.threads.max(1);

    // One engine (and persistent pool) per in-flight batch slot; adaptive
    // sessions provision for the deepest depth the controller may reach.
    // Engines are stateless between batches (cold-start reset per batch),
    // so slot assignment j % slots cannot change results.
    let slots = if adaptive { cfg.control.depth_max.max(depth) } else { depth };
    let engine_slots = if exec == PipelineExec::Threaded { slots } else { 1 };
    let mut engines = Vec::with_capacity(engine_slots);
    for _ in 0..engine_slots {
        let mut engine = build_engine(cfg, &graph, &topo)?;
        if task_threads > 1 {
            engine.set_pool(Arc::new(PersistentPool::new(task_threads)));
        }
        engine.reserve_batch(policy.max_batch);
        engine.reserve_atoms(dict0.k());
        engines.push(engine);
    }
    let combine_path = engines[0].combine_path();

    log(&format!(
        "serve[pipelined{}{}]: N={} M={} topology={} ({} directed edges, {} combine), B<={}, \
         depth={}, t={}, {} samples at {}",
        if adaptive { "-adaptive" } else { "" },
        if exec == PipelineExec::Reference { "-reference" } else { "" },
        cfg.agents,
        cfg.dim,
        cfg.topology,
        directed_edges,
        combine_path,
        policy.max_batch,
        depth,
        task_threads,
        cfg.samples,
        if cfg.rate > 0.0 { format!("{:.0} req/s", cfg.rate) } else { "saturation".into() },
    ));

    let obs = crate::obs::handle_for(&cfg.obs);
    let mut former =
        BatchFormer::with_capacity(policy, cfg.queue_capacity, stream).with_screen(screen);
    let mut updater = UpdaterState::new(cfg, dict0, directed_edges, depth, slots);
    updater.obs = obs.clone();
    let mode: &'static str = match (exec, adaptive) {
        (PipelineExec::Threaded, false) => "pipelined",
        (PipelineExec::Reference, false) => "pipelined-reference",
        (PipelineExec::Threaded, true) => "pipelined-adaptive",
        (PipelineExec::Reference, true) => "pipelined-adaptive-reference",
    };

    let t0 = Instant::now();
    let accum = match exec {
        PipelineExec::Reference => {
            run_reference(cfg, &mut former, updater, engines, depth, t0, &obs, log)?
        }
        PipelineExec::Threaded => {
            run_threaded_pipeline(cfg, &mut former, updater, engines, depth, t0, &obs, log)?
        }
    };

    let batches = accum.batch_losses.len();
    let shed = former.queue().shed_count() as usize;
    // Adaptive sessions report on the deterministic virtual clock (bit-
    // reproducible figures); static ones keep the measured wall clock.
    let duration_s = match accum.virtual_duration_us {
        Some(us) => (us as f64 / 1e6).max(1e-9),
        None => t0.elapsed().as_secs_f64().max(1e-9),
    };
    let (loss_first_quarter, loss_last_quarter) = loss_quarters(&accum.batch_losses);
    let pct = stats::Percentiles::new(&accum.latencies_ms);
    let served = accum.served;
    let report = ServeReport {
        mode,
        pipeline_depth: depth,
        samples: served,
        batches,
        shed,
        quarantined: former.quarantined_total() as usize,
        mean_batch: if batches > 0 { served as f64 / batches as f64 } else { 0.0 },
        duration_s,
        throughput_rps: served as f64 / duration_s,
        latency_p50_ms: pct.get(50.0),
        latency_p95_ms: pct.get(95.0),
        latency_p99_ms: pct.get(99.0),
        latency_max_ms: pct.max(),
        loss_first_quarter,
        loss_last_quarter,
        stats: accum.stats,
        combine_path,
        adaptive,
        slo_p99_ms: cfg.control.slo_p99_ms,
        slo_violation_frac: slo_violation_frac(&accum.latencies_ms, cfg.control.slo_p99_ms),
        decisions: accum.decisions,
        depth_trace: accum.depth_trace,
        conv_events: accum.conv_events,
        frozen_batches: accum.frozen_batches,
    };
    log(&format!(
        "serve[{}]: {} samples / {} batches in {:.3} s ({:.1} samples/s)",
        mode, report.samples, report.batches, report.duration_s, report.throughput_rps
    ));
    if let Some(n) = crate::obs::export(&cfg.obs, &obs)? {
        log(&format!(
            "trace: wrote {n} events to {}",
            cfg.obs.trace_path.as_deref().unwrap_or("?")
        ));
    }
    Ok((report, accum.dict))
}

/// Serial reference executor: the identical schedule, inline. Tokens
/// queue through a `VecDeque` exactly as they queue through the snapshot
/// channel in the threaded executor — one token popped per batch, policy
/// applied before the batch is formed, tokens re-emitted by the updater
/// (0, 1, or 2 per batch in adaptive mode).
///
/// Worker-death injection (`[serve] kill_slot`) is a no-op here: the
/// reference has no workers to kill, and because engines are stateless
/// between batches the threaded executor's re-dispatch reproduces this
/// executor's results bit-for-bit anyway — which is exactly the parity
/// check that proves a death loses no batch.
#[allow(clippy::too_many_arguments)]
fn run_reference(
    cfg: &ServeConfig,
    former: &mut BatchFormer,
    mut updater: UpdaterState,
    mut engines: Vec<DiffusionEngine>,
    depth: usize,
    t0: Instant,
    obs: &ObsHandle,
    log: &mut dyn FnMut(&str),
) -> Result<SessionAccum> {
    let engine = &mut engines[0];
    let params = serve_params(cfg);
    let task = serve_task(cfg);
    let queue = former.queue();
    let mut snaps: VecDeque<Token> = (0..depth)
        .map(|_| Token { snap: updater.fresh_snapshot(), policy: None })
        .collect();
    let mut j = 0usize;
    loop {
        let Some(token) = snaps.pop_front() else {
            return Err(DdlError::Runtime(
                "pipeline: snapshot token schedule broke (no token for the next batch)".into(),
            ));
        };
        if let Some(policy) = token.policy {
            queue.set_policy(policy);
        }
        let batch = match former.next_batch() {
            Some(b) => b,
            None => break,
        };
        let formed = Formed {
            at_us: former.now_us(),
            cap: queue.policy().max_batch,
            shed: former.take_shed(),
            quarantined: former.take_quarantined(),
        };
        // Residual admission-queue depth after the drain, on the
        // formation clock.
        obs.counter(formed.at_us, "queue_depth", Track::Stage("form"), queue.len() as f64);
        let snap = token.snap;
        {
            let refs: Vec<&[f32]> = batch.iter().map(|r| r.x.as_slice()).collect();
            engine.reserve_batch(refs.len());
            engine.reserve_atoms(snap.k());
            engine.reset();
            engine.run_batch(&snap, &task, &refs, params)?;
        }
        let stamp_ms = t0.elapsed().as_secs_f64() * 1e3;
        let view = engine.nu_view();
        updater.process(snap, &batch, &view, stamp_ms, formed, |t| snaps.push_back(t))?;
        j += 1;
        if j % 16 == 0 {
            log(&format!("  [reference] processed {j} batches"));
        }
    }
    Ok(updater.into_parts())
}

/// Threaded executor: formation on the calling thread, one inference
/// worker per engine slot, one updater thread; unbounded mpsc channels
/// (the circulating tokens themselves bound the number of batches in
/// flight to the current depth).
#[allow(clippy::too_many_arguments)]
fn run_threaded_pipeline(
    cfg: &ServeConfig,
    former: &mut BatchFormer,
    updater: UpdaterState,
    engines: Vec<DiffusionEngine>,
    depth: usize,
    t0: Instant,
    obs: &ObsHandle,
    log: &mut dyn FnMut(&str),
) -> Result<SessionAccum> {
    let params = serve_params(cfg);
    let task = serve_task(cfg);
    let n = cfg.agents;
    let m = cfg.dim;
    let slots = engines.len();

    let (snap_tx, snap_rx) = mpsc::channel::<Token>();
    let (done_tx, done_rx) = mpsc::channel::<Result<Done>>();
    let mut work_txs: Vec<mpsc::Sender<Work>> = Vec::with_capacity(slots);
    let mut work_rxs: Vec<Option<mpsc::Receiver<Work>>> = Vec::with_capacity(slots);
    for _ in 0..slots {
        let (tx, rx) = mpsc::channel::<Work>();
        work_txs.push(tx);
        work_rxs.push(Some(rx));
    }

    std::thread::scope(|scope| -> Result<SessionAccum> {
        // Stage 3: the updater consumes inference results in batch order
        // (out-of-order arrivals are buffered) and publishes tokens.
        let updater_handle = scope.spawn({
            let snap_tx = snap_tx.clone();
            let mut st = updater;
            move || -> Result<SessionAccum> {
                for _ in 0..depth {
                    // Prefill: S_0..S_{depth-1} = D_0.
                    let _ = snap_tx.send(Token { snap: st.fresh_snapshot(), policy: None });
                }
                let mut pending: BTreeMap<usize, Done> = BTreeMap::new();
                let mut next = 0usize;
                while let Ok(result) = done_rx.recv() {
                    let done = result?;
                    pending.insert(done.j, done);
                    while let Some(d) = pending.remove(&next) {
                        let Done { snap, batch, v, b, stamp_ms, formed, .. } = d;
                        let view = NuView::new(&v, n, m, b);
                        st.process(snap, &batch, &view, stamp_ms, formed, |t| {
                            // Main may have stopped listening (teardown) —
                            // the schedule itself stays intact.
                            let _ = snap_tx.send(t);
                        })?;
                        next += 1;
                    }
                }
                if !pending.is_empty() {
                    return Err(DdlError::Runtime(
                        "pipeline: inference results lost before completion".into(),
                    ));
                }
                Ok(st.into_parts())
            }
        });

        // Stage 2: inference workers (slot w serves batches j ≡ w mod
        // slots). `[serve] kill_slot` marks one slot as a deterministic
        // fault-injection victim: on the first batch with index ≥
        // `kill_at_batch` it discards the received Work and exits —
        // death mid-batch, the batch lost with the worker. The
        // dispatcher (which knows the same config) re-dispatches.
        let kill_slot = cfg.kill_slot.filter(|&s| s < slots);
        let mut worker_handles = Vec::with_capacity(slots);
        for (w, mut engine) in engines.into_iter().enumerate() {
            let work_rx = work_rxs[w].take().ok_or_else(|| {
                DdlError::Runtime(format!("pipeline worker {w} receiver already taken"))
            })?;
            let die_at = (kill_slot == Some(w)).then_some(cfg.kill_at_batch);
            let done_tx = done_tx.clone();
            worker_handles.push(scope.spawn(move || {
                while let Ok(Work { j, snap, batch, formed }) = work_rx.recv() {
                    if die_at.is_some_and(|at| j >= at) {
                        // Worker death mid-batch: the Work is dropped
                        // unreported and the thread exits (its done_tx
                        // closes with it).
                        break;
                    }
                    let res = {
                        let refs: Vec<&[f32]> = batch.iter().map(|r| r.x.as_slice()).collect();
                        engine.reserve_batch(refs.len());
                        engine.reserve_atoms(snap.k());
                        engine.reset();
                        engine.run_batch(&snap, &task, &refs, params)
                    };
                    let b = batch.len();
                    let stamp_ms = t0.elapsed().as_secs_f64() * 1e3;
                    let out = res.map(|_| Done {
                        j,
                        v: engine.nu_view().to_owned_data(),
                        b,
                        stamp_ms,
                        formed,
                        snap,
                        batch,
                    });
                    let failed = out.is_err();
                    if done_tx.send(out).is_err() || failed {
                        break;
                    }
                }
            }));
        }
        drop(done_tx);
        drop(snap_tx);

        // Stage 1: token wait + formation + dispatch on this thread.
        // `snap_rx.recv` blocks only when every circulating token is
        // attached to an in-flight batch — that is the pipeline's
        // back-pressure. A token is consumed *before* its batch is formed
        // so a piggybacked policy decision applies at a deterministic
        // point of the batch sequence. Admission itself (inside
        // `next_batch`) never blocks.
        let queue = former.queue();
        let mut dispatched = 0usize;
        // Live-slot set for deterministic batch re-dispatch after the
        // injected worker death (slot choice cannot change results:
        // engines are stateless between batches).
        let mut live: Vec<usize> = (0..slots).collect();
        let mut dead: Option<usize> = None;
        loop {
            let token = match snap_rx.recv() {
                Ok(t) => t,
                Err(_) => break, // updater exited early; error surfaces below
            };
            if let Some(policy) = token.policy {
                queue.set_policy(policy);
            }
            let batch = match former.next_batch() {
                Some(b) => b,
                None => break,
            };
            let formed = Formed {
                at_us: former.now_us(),
                cap: queue.policy().max_batch,
                shed: former.take_shed(),
                quarantined: former.take_quarantined(),
            };
            // Formation-side gauge; in the threaded executor this
            // interleaves with the updater's events in recorder order
            // (timestamps, not order, are the deterministic part — see
            // the module docs in `crate::obs`).
            obs.counter(formed.at_us, "queue_depth", Track::Stage("form"), queue.len() as f64);
            let target = dispatched % slots;
            let work = Work { j: dispatched, snap: token.snap, batch, formed };
            if dead != Some(target) && kill_slot == Some(target) && dispatched >= cfg.kill_at_batch
            {
                // This dispatch kills the victim mid-batch. The batch is
                // cloned *before* the fatal send, the victim's copy dies
                // with it, and the clone goes to the next live slot — so
                // the updater still sees every batch exactly once, in
                // order, and the token count is conserved (the clone's
                // snapshot is the one recycled).
                if live.len() <= 1 {
                    return Err(DdlError::Runtime(
                        "pipeline: kill_slot would kill the last inference worker \
                         (need pipeline depth >= 2 to survive a death)"
                            .into(),
                    ));
                }
                let clone = Work {
                    j: work.j,
                    snap: work.snap.clone(),
                    batch: work.batch.clone(),
                    formed,
                };
                let _ = work_txs[target].send(work);
                live.retain(|&s| s != target);
                dead = Some(target);
                let to = live[dispatched % live.len()];
                if obs.enabled() {
                    obs.instant(
                        formed.at_us,
                        "worker_death",
                        Track::Stage("infer"),
                        vec![
                            ("slot", ArgValue::U(target as u64)),
                            ("j", ArgValue::U(dispatched as u64)),
                        ],
                    );
                    obs.instant(
                        formed.at_us,
                        "batch_redispatch",
                        Track::Stage("infer"),
                        vec![
                            ("j", ArgValue::U(dispatched as u64)),
                            ("from", ArgValue::U(target as u64)),
                            ("to", ArgValue::U(to as u64)),
                        ],
                    );
                }
                if work_txs[to].send(clone).is_err() {
                    break; // worker exited early; error surfaces below
                }
            } else {
                // Batches whose modulo slot is dead re-route to a live
                // slot by the same deterministic rule.
                let to = if dead == Some(target) { live[dispatched % live.len()] } else { target };
                if work_txs[to].send(work).is_err() {
                    break; // worker exited early; error surfaces below
                }
            }
            dispatched += 1;
            if dispatched % 16 == 0 {
                log(&format!("  [pipeline] dispatched {dispatched} batches"));
            }
        }
        drop(work_txs);
        drop(snap_rx);

        for h in worker_handles {
            h.join().map_err(|_| DdlError::Runtime("pipeline: inference worker panicked".into()))?;
        }
        updater_handle
            .join()
            .map_err(|_| DdlError::Runtime("pipeline: updater thread panicked".into()))?
    })
}
