//! Pipelined serving: overlap batch formation, diffusion inference, and
//! the Eq. 51 online update across threads.
//!
//! The serial session (`serve/session.rs`) is a single-server loop:
//! admission, the diffusion sweep, and the dictionary update run
//! back-to-back on one thread, so the engine's worker pool idles during
//! queueing and adaptation. This module restructures the session into a
//! three-stage concurrent pipeline:
//!
//! 1. **Formation** (main thread) — the [`BatchFormer`] replays the arrival
//!    stream through the micro-batching policy on the virtual clock and
//!    forms batch `i+1` while batch `i` computes. Formation never consults
//!    service times, so batch composition is a pure function of the stream
//!    and the policy — the determinism anchor of the whole pipeline.
//! 2. **Inference** (worker threads + persistent pools) — up to
//!    `pipeline_depth` batches are in flight, each a
//!    [`DiffusionEngine::run_batch`] sweep against an immutable dictionary
//!    *snapshot* on a long-lived [`crate::net::PersistentPool`].
//! 3. **Update** (dedicated updater thread) — primal recovery, statistics,
//!    and the Eq. 51 update ([`crate::learn::recover_and_stats`] /
//!    [`crate::learn::apply_eq51_update`]) run against the **write** side of
//!    a [`DictDoubleBuffer`] while inference reads published snapshots —
//!    inference never blocks on the update.
//!
//! ## The fixed swap schedule (bounded staleness)
//!
//! Let `D_j` be the dictionary after the updates of batches `0..j`
//! (`D_0` = initial). With pipeline depth `D`, batch `j` is inferred
//! against the snapshot `S_j = D_{max(0, j − D)}`: updates lag inference by
//! exactly the pipeline depth, never "whatever happened to be published"
//! — the schedule is data-independent, so the final dictionary, per-batch
//! losses, and ψ-traffic are **bit-identical** for the threaded executor
//! and the serial reference executor ([`PipelineExec::Reference`]), at any
//! thread count and depth. The speedup is pure overlap, not a silently
//! different algorithm. This is the scheme D4L (Koppel et al. 2016) and
//! Daneshmand et al. (2016) use to overlap local optimization with
//! communication, made deterministic.
//!
//! Depth 1 is the classic three-stage pipeline (update of batch `i−1`
//! overlaps inference of batch `i`); depth ≥ 2 additionally overlaps
//! consecutive inference sweeps (batch `i+1` depends on `U_{i−1}`, not on
//! batch `i`), which is where the throughput multiplier comes from when
//! cores outnumber the engine's thread count.
//!
//! Wall-clock metrics (throughput, latency percentiles) are measured on
//! the real clock and naturally differ between executors; the parity
//! contract covers dictionaries, sample/batch counts, losses, and
//! [`MessageStats`].

use crate::config::experiment::ServeConfig;
use crate::error::{DdlError, Result};
use crate::infer::{DiffusionEngine, NuView};
use crate::learn::{apply_eq51_update, recover_and_stats};
use crate::math::stats;
use crate::model::{DictDoubleBuffer, DistributedDictionary, TaskSpec};
use crate::net::{MessageStats, PersistentPool};
use crate::ops::prox::DictProx;
use crate::serve::queue::{BatchPolicy, Request, SharedQueue};
use crate::serve::session::{
    build_engine, loss_quarters, serve_params, serve_task, setup, ServeReport, SessionSetup,
};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Which executor runs the pipeline schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineExec {
    /// Three-stage concurrent executor (production path).
    Threaded,
    /// Single-threaded reference executor of the identical schedule — the
    /// comparator for the bitwise parity tests.
    Reference,
}

/// Service-independent batch formation: replays the arrival stream through
/// the micro-batching policy on the virtual clock, jumping only to arrival
/// and deadline events. Unlike the serial session's single-server loop,
/// the clock never advances by service time — admission and formation are
/// decoupled from inference, so batch `i+1` forms while batch `i` is in
/// flight and the batch sequence is a deterministic function of
/// `(stream, policy)` alone.
///
/// Admission goes through a [`SharedQueue`]; [`Self::queue`] exposes the
/// handle so external producers can inject requests concurrently in a real
/// deployment (the replayed-stream sessions used for parity and benches
/// are single-producer).
pub struct BatchFormer {
    queue: Arc<SharedQueue>,
    stream: VecDeque<(u64, Vec<f32>)>,
    now_us: u64,
}

impl BatchFormer {
    /// Former over `stream` (`(arrival_us, x)` pairs in arrival order).
    pub fn new(policy: BatchPolicy, stream: Vec<(u64, Vec<f32>)>) -> Self {
        BatchFormer {
            queue: Arc::new(SharedQueue::new(policy)),
            stream: stream.into(),
            now_us: 0,
        }
    }

    /// The shared admission queue.
    pub fn queue(&self) -> Arc<SharedQueue> {
        Arc::clone(&self.queue)
    }

    /// Current virtual-clock reading (µs).
    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Form the next batch, or `None` when the stream is exhausted and the
    /// queue drained. Partial batches release at the max-wait deadline;
    /// end-of-stream flushes the remainder immediately (nothing else will
    /// arrive), exactly like the serial session.
    pub fn next_batch(&mut self) -> Option<Vec<Request>> {
        loop {
            // Admit every request that has arrived by the current clock.
            while self.stream.front().is_some_and(|(t, _)| *t <= self.now_us) {
                let (t, x) = self.stream.pop_front().expect("front checked");
                self.queue.push(x, t);
            }
            if self.queue.ready(self.now_us) {
                return Some(self.queue.drain_batch());
            }
            match self.stream.front() {
                None => {
                    if self.queue.is_empty() {
                        return None;
                    }
                    return Some(self.queue.drain_batch());
                }
                Some(&(t_arrival, _)) => {
                    // Idle: jump to the next arrival or batch deadline.
                    let mut t_next = t_arrival;
                    if let Some(d) = self.queue.next_deadline_us() {
                        t_next = t_next.min(d);
                    }
                    self.now_us = self.now_us.max(t_next);
                }
            }
        }
    }
}

/// Stage-3 state: the double-buffered dictionary plus every deterministic
/// accumulator of the session (losses, traffic, served counts). Both
/// executors drive batches through [`Self::process`] in batch order, which
/// is what makes them bit-identical.
struct UpdaterState {
    dict: DictDoubleBuffer,
    task: TaskSpec,
    prox: DictProx,
    mu_w: f32,
    m: usize,
    iters: usize,
    directed_edges: usize,
    ys: Vec<f32>,
    corr: Vec<f32>,
    mean: Vec<f32>,
    batch_losses: Vec<f64>,
    stats: MessageStats,
    served: usize,
    /// Per-request latency: wall-clock inference completion (ms since
    /// session start — the moment the result is servable; the Eq. 51
    /// update continues in the background) minus the request's virtual
    /// arrival offset, clamped at 0.
    latencies_ms: Vec<f64>,
}

impl UpdaterState {
    fn new(cfg: &ServeConfig, dict0: DistributedDictionary, directed_edges: usize) -> Self {
        UpdaterState {
            dict: DictDoubleBuffer::new(dict0),
            task: serve_task(cfg),
            prox: DictProx::None,
            mu_w: cfg.mu_w,
            m: cfg.dim,
            iters: cfg.infer.iters,
            directed_edges,
            ys: Vec::new(),
            corr: Vec::new(),
            mean: Vec::new(),
            batch_losses: Vec::new(),
            stats: MessageStats::default(),
            served: 0,
            latencies_ms: Vec::new(),
        }
    }

    /// A fresh copy of the latest published snapshot (pipeline prefill).
    fn fresh_snapshot(&self) -> DistributedDictionary {
        self.dict.read().clone()
    }

    /// Process batch `j`'s inference result: recovery + stats against the
    /// snapshot `S_j` the batch was inferred with, publish `S_{j+depth}`
    /// (the authoritative state *before* this batch's update, recycling the
    /// `S_j` buffer) through `emit`, then apply the Eq. 51 update to the
    /// write buffer. `emit` fires before the update so a depth-1 pipeline
    /// genuinely overlaps `U_j` with the next batch's inference.
    fn process(
        &mut self,
        mut snap: DistributedDictionary,
        batch: &[Request],
        view: &NuView<'_>,
        stamp_ms: f64,
        emit: impl FnOnce(DistributedDictionary),
    ) -> Result<()> {
        let refs: Vec<&[f32]> = batch.iter().map(|r| r.x.as_slice()).collect();
        let tstats = recover_and_stats(
            &snap,
            &self.task,
            &refs,
            view,
            &mut self.ys,
            &mut self.corr,
            &mut self.mean,
        )?;
        self.batch_losses.push(tstats.mean_loss);
        self.served += batch.len();
        for r in batch {
            // Completion − arrival, like the serial executor. The pipeline
            // replays virtual arrivals at full speed, so a request can
            // complete before its arrival offset would have elapsed in real
            // time — clamp to 0 (the pipeline outran the arrival process).
            self.latencies_ms.push((stamp_ms - r.arrival_us as f64 / 1e3).max(0.0));
        }
        // ψ traffic, accounted exactly as the serial session does: one
        // message per directed edge per diffusion iteration carrying the
        // whole minibatch (see `serve/session.rs`).
        self.stats.record_exchange(self.directed_edges * self.iters, batch.len() * self.m);
        self.stats.add_rounds(self.iters);

        // Publish S_{j+depth} = D_j: swap the double buffer (read becomes
        // the authoritative pre-update state) and recycle the S_j buffer.
        self.dict.publish();
        snap.copy_from(self.dict.read())?;
        emit(snap);

        // Eq. 51 into the write buffer: D_j → D_{j+1}. Inference of later
        // batches reads published snapshots, never this buffer.
        apply_eq51_update(
            self.dict.write_mut(),
            &self.task,
            self.prox,
            self.mu_w,
            &self.ys,
            view,
        );
        Ok(())
    }

    fn into_parts(
        self,
    ) -> (DistributedDictionary, Vec<f64>, MessageStats, usize, Vec<f64>) {
        (self.dict.into_write(), self.batch_losses, self.stats, self.served, self.latencies_ms)
    }
}

/// Dispatch of one formed batch to an inference worker.
struct Work {
    j: usize,
    snap: DistributedDictionary,
    batch: Vec<Request>,
}

/// One completed inference: the shipped dual iterates plus everything the
/// updater needs (the snapshot travels back for recovery and recycling).
struct Done {
    j: usize,
    snap: DistributedDictionary,
    batch: Vec<Request>,
    v: Vec<f32>,
    b: usize,
    stamp_ms: f64,
}

/// Run the pipelined session. Returns the report and the final adapted
/// dictionary (for bitwise parity checks).
pub fn run_pipelined(
    cfg: &ServeConfig,
    exec: PipelineExec,
    log: &mut dyn FnMut(&str),
) -> Result<(ServeReport, DistributedDictionary)> {
    let depth = cfg.pipeline_depth.max(1);
    let SessionSetup { graph, topo, dict0, stream } = setup(cfg)?;
    let directed_edges = 2 * graph.edge_count();
    let policy = BatchPolicy::new(cfg.batch, cfg.max_wait_us);
    let task_threads = cfg.infer.threads.max(1);

    // One engine (and persistent pool) per in-flight batch slot. Engines
    // are stateless between batches (cold-start reset per batch), so slot
    // assignment j % depth cannot change results.
    let engine_slots = if exec == PipelineExec::Threaded { depth } else { 1 };
    let mut engines = Vec::with_capacity(engine_slots);
    for _ in 0..engine_slots {
        let mut engine = build_engine(cfg, &graph, &topo)?;
        if task_threads > 1 {
            engine.set_pool(Arc::new(PersistentPool::new(task_threads)));
        }
        engine.reserve_batch(cfg.batch.max(1));
        engine.reserve_atoms(dict0.k());
        engines.push(engine);
    }
    let combine_path = engines[0].combine_path();

    log(&format!(
        "serve[pipelined{}]: N={} M={} topology={} ({} directed edges, {} combine), B<={}, \
         depth={}, t={}, {} samples at {}",
        if exec == PipelineExec::Reference { "-reference" } else { "" },
        cfg.agents,
        cfg.dim,
        cfg.topology,
        directed_edges,
        combine_path,
        cfg.batch.max(1),
        depth,
        task_threads,
        cfg.samples,
        if cfg.rate > 0.0 { format!("{:.0} req/s", cfg.rate) } else { "saturation".into() },
    ));

    let mut former = BatchFormer::new(policy, stream);
    let updater = UpdaterState::new(cfg, dict0, directed_edges);
    let mode: &'static str = match exec {
        PipelineExec::Threaded => "pipelined",
        PipelineExec::Reference => "pipelined-reference",
    };

    let t0 = Instant::now();
    let (dict, batch_losses, msg_stats, served, latencies_ms) = match exec {
        PipelineExec::Reference => {
            run_reference(cfg, &mut former, updater, engines, depth, t0, log)?
        }
        PipelineExec::Threaded => {
            run_threaded_pipeline(cfg, &mut former, updater, engines, depth, t0, log)?
        }
    };

    let batches = batch_losses.len();
    let duration_s = t0.elapsed().as_secs_f64().max(1e-9);
    let (loss_first_quarter, loss_last_quarter) = loss_quarters(&batch_losses);
    let report = ServeReport {
        mode,
        pipeline_depth: depth,
        samples: served,
        batches,
        mean_batch: if batches > 0 { served as f64 / batches as f64 } else { 0.0 },
        duration_s,
        throughput_rps: served as f64 / duration_s,
        latency_p50_ms: stats::percentile(&latencies_ms, 50.0),
        latency_p95_ms: stats::percentile(&latencies_ms, 95.0),
        latency_p99_ms: stats::percentile(&latencies_ms, 99.0),
        latency_max_ms: latencies_ms.iter().cloned().fold(0.0, f64::max),
        loss_first_quarter,
        loss_last_quarter,
        stats: msg_stats,
        combine_path,
    };
    log(&format!(
        "serve[{}]: {} samples / {} batches in {:.3} s ({:.1} samples/s)",
        mode, report.samples, report.batches, report.duration_s, report.throughput_rps
    ));
    Ok((report, dict))
}

type SessionOut = (DistributedDictionary, Vec<f64>, MessageStats, usize, Vec<f64>);

/// Serial reference executor: the identical schedule, inline. Snapshots
/// queue through a `VecDeque` exactly as they queue through the snapshot
/// channel in the threaded executor.
fn run_reference(
    cfg: &ServeConfig,
    former: &mut BatchFormer,
    mut updater: UpdaterState,
    mut engines: Vec<DiffusionEngine>,
    depth: usize,
    t0: Instant,
    log: &mut dyn FnMut(&str),
) -> Result<SessionOut> {
    let engine = &mut engines[0];
    let params = serve_params(cfg);
    let task = serve_task(cfg);
    let mut snaps: VecDeque<DistributedDictionary> =
        (0..depth).map(|_| updater.fresh_snapshot()).collect();
    let mut j = 0usize;
    while let Some(batch) = former.next_batch() {
        let snap = snaps.pop_front().expect("snapshot schedule invariant");
        {
            let refs: Vec<&[f32]> = batch.iter().map(|r| r.x.as_slice()).collect();
            engine.reserve_batch(refs.len());
            engine.reserve_atoms(snap.k());
            engine.reset();
            engine.run_batch(&snap, &task, &refs, params)?;
        }
        let stamp_ms = t0.elapsed().as_secs_f64() * 1e3;
        let view = engine.nu_view();
        updater.process(snap, &batch, &view, stamp_ms, |s| snaps.push_back(s))?;
        j += 1;
        if j % 16 == 0 {
            log(&format!("  [reference] processed {j} batches"));
        }
    }
    Ok(updater.into_parts())
}

/// Threaded executor: formation on the calling thread, `depth` inference
/// workers, one updater thread; unbounded mpsc channels (the snapshot
/// schedule itself bounds the number of batches in flight to `depth`).
fn run_threaded_pipeline(
    cfg: &ServeConfig,
    former: &mut BatchFormer,
    updater: UpdaterState,
    engines: Vec<DiffusionEngine>,
    depth: usize,
    t0: Instant,
    log: &mut dyn FnMut(&str),
) -> Result<SessionOut> {
    let params = serve_params(cfg);
    let task = serve_task(cfg);
    let n = cfg.agents;
    let m = cfg.dim;

    let (snap_tx, snap_rx) = mpsc::channel::<DistributedDictionary>();
    let (done_tx, done_rx) = mpsc::channel::<Result<Done>>();
    let mut work_txs: Vec<mpsc::Sender<Work>> = Vec::with_capacity(depth);
    let mut work_rxs: Vec<Option<mpsc::Receiver<Work>>> = Vec::with_capacity(depth);
    for _ in 0..depth {
        let (tx, rx) = mpsc::channel::<Work>();
        work_txs.push(tx);
        work_rxs.push(Some(rx));
    }

    std::thread::scope(|scope| -> Result<SessionOut> {
        // Stage 3: the updater consumes inference results in batch order
        // (out-of-order arrivals are buffered) and publishes snapshots.
        let updater_handle = scope.spawn({
            let snap_tx = snap_tx.clone();
            let mut st = updater;
            move || -> Result<SessionOut> {
                for _ in 0..depth {
                    // Prefill: S_0..S_{depth-1} = D_0.
                    let _ = snap_tx.send(st.fresh_snapshot());
                }
                let mut pending: BTreeMap<usize, Done> = BTreeMap::new();
                let mut next = 0usize;
                while let Ok(result) = done_rx.recv() {
                    let done = result?;
                    pending.insert(done.j, done);
                    while let Some(d) = pending.remove(&next) {
                        let Done { snap, batch, v, b, stamp_ms, .. } = d;
                        let view = NuView::new(&v, n, m, b);
                        st.process(snap, &batch, &view, stamp_ms, |s| {
                            // Main may have stopped listening (teardown) —
                            // the schedule itself stays intact.
                            let _ = snap_tx.send(s);
                        })?;
                        next += 1;
                    }
                }
                if !pending.is_empty() {
                    return Err(DdlError::Runtime(
                        "pipeline: inference results lost before completion".into(),
                    ));
                }
                Ok(st.into_parts())
            }
        });

        // Stage 2: inference workers (slot w serves batches j ≡ w mod D).
        let mut worker_handles = Vec::with_capacity(depth);
        for (w, mut engine) in engines.into_iter().enumerate() {
            let work_rx = work_rxs[w].take().expect("one receiver per worker");
            let done_tx = done_tx.clone();
            worker_handles.push(scope.spawn(move || {
                while let Ok(Work { j, snap, batch }) = work_rx.recv() {
                    let res = {
                        let refs: Vec<&[f32]> = batch.iter().map(|r| r.x.as_slice()).collect();
                        engine.reserve_batch(refs.len());
                        engine.reserve_atoms(snap.k());
                        engine.reset();
                        engine.run_batch(&snap, &task, &refs, params)
                    };
                    let b = batch.len();
                    let stamp_ms = t0.elapsed().as_secs_f64() * 1e3;
                    let out = res.map(|_| Done {
                        j,
                        v: engine.nu_view().to_owned_data(),
                        b,
                        stamp_ms,
                        snap,
                        batch,
                    });
                    let failed = out.is_err();
                    if done_tx.send(out).is_err() || failed {
                        break;
                    }
                }
            }));
        }
        drop(done_tx);
        drop(snap_tx);

        // Stage 1: formation + dispatch on this thread. `snap_rx.recv`
        // blocks only when `depth` batches are already in flight — that is
        // the pipeline's back-pressure. Admission itself (inside
        // `next_batch`) never blocks.
        let mut dispatched = 0usize;
        while let Some(batch) = former.next_batch() {
            match snap_rx.recv() {
                Ok(snap) => {
                    if work_txs[dispatched % depth]
                        .send(Work { j: dispatched, snap, batch })
                        .is_err()
                    {
                        break; // worker exited early; error surfaces below
                    }
                    dispatched += 1;
                    if dispatched % 16 == 0 {
                        log(&format!("  [pipeline] dispatched {dispatched} batches"));
                    }
                }
                Err(_) => break, // updater exited early; error surfaces below
            }
        }
        drop(work_txs);
        drop(snap_rx);

        for h in worker_handles {
            h.join().map_err(|_| DdlError::Runtime("pipeline: inference worker panicked".into()))?;
        }
        updater_handle
            .join()
            .map_err(|_| DdlError::Runtime("pipeline: updater thread panicked".into()))?
    })
}
