//! Micro-batching admission queue for the streaming inference service.
//!
//! Requests (one data sample each) are admitted with an arrival timestamp
//! and drained as minibatches formed by the classic two-knob policy:
//!
//! * **max-size** — a batch closes as soon as `max_batch` requests wait;
//! * **max-wait** — a partial batch closes once its *oldest* request has
//!   waited `max_wait_us`, bounding the queueing-latency a sample can pay
//!   for the throughput of its batch mates.
//!
//! Time is an explicit `u64` microsecond clock supplied by the caller, so
//! the queue is fully deterministic (the session loop feeds it either
//! simulated arrival offsets or measured wall-clock offsets) and trivially
//! testable. The queue is FIFO: batches preserve admission order, which
//! keeps the per-sample ν trajectories reproducible for a given stream.
//!
//! [`SharedQueue`] is the thread-safe admission handle for the pipelined
//! session: every operation takes the internal lock only for the queue
//! bookkeeping itself — a popped batch is *moved out* before inference
//! starts — so **admission never blocks while a batch is in flight**
//! (property-tested in `tests/serve_pipeline_parity.rs`).
//!
//! Admission can be **bounded** ([`MicroBatchQueue::with_capacity`],
//! `[serve] queue_capacity`): [`MicroBatchQueue::try_push`] rejects with
//! the typed [`DdlError::QueueFull`] once `capacity` requests wait, and
//! the queue counts sheds so the session loop and the adaptive batch
//! controller can observe overflow storms instead of queueing without
//! limit. Capacity `0` (the default) keeps the historical unbounded
//! behavior, and the infallible [`MicroBatchQueue::push`] always admits.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::error::{DdlError, Result};

/// Batch-formation policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Largest minibatch handed to the engine (`B`); clamped to ≥ 1.
    pub max_batch: usize,
    /// Longest time (µs) the oldest queued request may wait before a
    /// partial batch is released. `0` releases on every poll.
    pub max_wait_us: u64,
}

impl BatchPolicy {
    /// Policy with the given knobs (max_batch clamped to ≥ 1).
    pub fn new(max_batch: usize, max_wait_us: u64) -> Self {
        BatchPolicy { max_batch: max_batch.max(1), max_wait_us }
    }
}

/// One admitted inference request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Monotone admission id (also the reply correlation id).
    pub id: u64,
    /// Arrival time on the queue's microsecond clock.
    pub arrival_us: u64,
    /// The data sample `x ∈ R^M`.
    pub x: Vec<f32>,
}

/// FIFO micro-batching queue.
#[derive(Debug)]
pub struct MicroBatchQueue {
    policy: BatchPolicy,
    pending: VecDeque<Request>,
    next_id: u64,
    /// Admission bound for [`Self::try_push`]; `0` = unbounded.
    capacity: usize,
    /// Requests rejected by [`Self::try_push`] since construction.
    shed: u64,
}

impl MicroBatchQueue {
    /// Empty unbounded queue under `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        Self::with_capacity(policy, 0)
    }

    /// Empty queue under `policy` with a bounded admission capacity
    /// (`0` = unbounded, identical to [`Self::new`]).
    pub fn with_capacity(policy: BatchPolicy, capacity: usize) -> Self {
        MicroBatchQueue { policy, pending: VecDeque::new(), next_id: 0, capacity, shed: 0 }
    }

    /// The admission bound (`0` = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Requests rejected by [`Self::try_push`] so far.
    pub fn shed_count(&self) -> u64 {
        self.shed
    }

    /// The active policy.
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Swap the batch-formation policy in place (the adaptive batch
    /// controller re-decides the knobs each control tick). Already-queued
    /// requests are re-judged under the new policy on the next
    /// [`Self::ready`]/[`Self::pop_batch`] call; admission order is
    /// untouched.
    pub fn set_policy(&mut self, policy: BatchPolicy) {
        self.policy = BatchPolicy::new(policy.max_batch, policy.max_wait_us);
    }

    /// Admit a sample at `now_us` unconditionally (ignores the capacity
    /// bound); returns its request id.
    pub fn push(&mut self, x: Vec<f32>, now_us: u64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.pending.push_back(Request { id, arrival_us: now_us, x });
        id
    }

    /// Admit a sample at `now_us` respecting the capacity bound. A full
    /// queue sheds the sample: the shed counter bumps and the typed
    /// [`DdlError::QueueFull`] comes back (the sample is dropped, *not*
    /// queued; ids are only consumed by admitted requests, so a shed
    /// leaves the id sequence — and hence replay — untouched).
    pub fn try_push(&mut self, x: Vec<f32>, now_us: u64) -> Result<u64> {
        if self.capacity > 0 && self.pending.len() >= self.capacity {
            self.shed += 1;
            return Err(DdlError::QueueFull { capacity: self.capacity });
        }
        Ok(self.push(x, now_us))
    }

    /// Number of queued requests.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Arrival time of the oldest queued request.
    pub fn oldest_arrival_us(&self) -> Option<u64> {
        self.pending.front().map(|r| r.arrival_us)
    }

    /// Earliest time at which [`Self::ready`] will hold without further
    /// admissions (the max-wait deadline of the oldest request), if any
    /// request is queued. Full batches are ready immediately.
    pub fn next_deadline_us(&self) -> Option<u64> {
        let oldest = self.oldest_arrival_us()?;
        if self.pending.len() >= self.policy.max_batch {
            Some(oldest)
        } else {
            Some(oldest.saturating_add(self.policy.max_wait_us))
        }
    }

    /// Whether a batch should be released at `now_us`: the queue holds a
    /// full `max_batch`, or the oldest request has waited `max_wait_us`.
    pub fn ready(&self, now_us: u64) -> bool {
        if self.pending.len() >= self.policy.max_batch {
            return true;
        }
        match self.oldest_arrival_us() {
            Some(oldest) => now_us.saturating_sub(oldest) >= self.policy.max_wait_us,
            None => false,
        }
    }

    /// Release the next batch (up to `max_batch` oldest requests) if
    /// [`Self::ready`]; `None` otherwise.
    pub fn pop_batch(&mut self, now_us: u64) -> Option<Vec<Request>> {
        if !self.ready(now_us) {
            return None;
        }
        Some(self.drain_batch())
    }

    /// Unconditionally release the next (possibly partial) batch —
    /// end-of-stream drain.
    pub fn drain_batch(&mut self) -> Vec<Request> {
        let take = self.policy.max_batch.min(self.pending.len());
        self.pending.drain(..take).collect()
    }
}

/// L2 norm of one sample vector, accumulated in f64 in index order — the
/// screen statistic is a pure function of the sample bits, so poisoning
/// screens replay bit-identically.
pub fn sample_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Robust threshold for the poisoned-sample screen over a set of sample
/// norms: `median + max(z · 1.4826 · MAD, 0.5 · median)`.
///
/// The MAD term is the classic robust scale estimate (breakdown point
/// 50%, far above any realistic poison fraction); the `0.5 · median`
/// floor keeps the screen from turning paranoid on tightly-clustered
/// honest streams, where MAD ≈ 0 would otherwise quarantine every sample
/// a hair above the median. Sorts use `total_cmp`, so the threshold is a
/// deterministic function of the norm multiset. An empty slice yields
/// `+∞` (the screen is inert).
pub fn poison_norm_threshold(norms: &[f64], z: f64) -> f64 {
    if norms.is_empty() {
        return f64::INFINITY;
    }
    let mut sorted = norms.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let med = sorted[sorted.len() / 2];
    let mut dev: Vec<f64> = sorted.iter().map(|&n| (n - med).abs()).collect();
    dev.sort_by(|a, b| a.total_cmp(b));
    let mad = dev[dev.len() / 2];
    med + (z * 1.4826 * mad).max(0.5 * med)
}

/// Split a formed batch into `(kept, quarantined)` by the norm screen:
/// samples whose L2 norm exceeds `threshold` are quarantined before they
/// can reach the Eq. 51 update. The minimum-norm sample is always kept so
/// a batch never screens down to empty (the engine requires B ≥ 1), and
/// admission order is preserved within both halves.
pub fn screen_batch(batch: Vec<Request>, threshold: f64) -> (Vec<Request>, Vec<Request>) {
    if batch.is_empty() {
        return (batch, Vec::new());
    }
    let norms: Vec<f64> = batch.iter().map(|r| sample_norm(&r.x)).collect();
    let mut min_i = 0usize;
    for (i, &n) in norms.iter().enumerate() {
        if n < norms[min_i] {
            min_i = i;
        }
    }
    let mut kept = Vec::with_capacity(batch.len());
    let mut quarantined = Vec::new();
    for (i, r) in batch.into_iter().enumerate() {
        if norms[i] <= threshold || i == min_i {
            kept.push(r);
        } else {
            quarantined.push(r);
        }
    }
    (kept, quarantined)
}

/// Concurrent admission handle over a [`MicroBatchQueue`].
///
/// Producers push from any thread; the pipeline's formation stage pops
/// batches. The `Mutex` guards only O(1)/O(B) queue bookkeeping — batches
/// are moved out under the lock and processed outside it, so admission
/// latency is independent of inference time: a request can always be
/// admitted while a batch is in flight.
#[derive(Debug)]
pub struct SharedQueue {
    inner: Mutex<MicroBatchQueue>,
}

impl SharedQueue {
    /// Empty unbounded shared queue under `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        Self::with_capacity(policy, 0)
    }

    /// Empty shared queue under `policy` with a bounded admission
    /// capacity (`0` = unbounded).
    pub fn with_capacity(policy: BatchPolicy, capacity: usize) -> Self {
        SharedQueue { inner: Mutex::new(MicroBatchQueue::with_capacity(policy, capacity)) }
    }

    /// The admission bound (`0` = unbounded).
    pub fn capacity(&self) -> usize {
        self.lock().capacity()
    }

    /// Requests rejected by [`Self::try_push`] so far.
    pub fn shed_count(&self) -> u64 {
        self.lock().shed_count()
    }

    /// The active policy (copied out under the lock; the policy is
    /// swappable at runtime via [`Self::set_policy`]).
    pub fn policy(&self) -> BatchPolicy {
        self.lock().policy()
    }

    /// Swap the batch-formation policy (see
    /// [`MicroBatchQueue::set_policy`]).
    pub fn set_policy(&self, policy: BatchPolicy) {
        self.lock().set_policy(policy);
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, MicroBatchQueue> {
        self.inner.lock().expect("SharedQueue: poisoned lock")
    }

    /// Admit a sample at `now_us` unconditionally; returns its request id.
    pub fn push(&self, x: Vec<f32>, now_us: u64) -> u64 {
        self.lock().push(x, now_us)
    }

    /// Admit a sample at `now_us` respecting the capacity bound (see
    /// [`MicroBatchQueue::try_push`]).
    pub fn try_push(&self, x: Vec<f32>, now_us: u64) -> Result<u64> {
        self.lock().try_push(x, now_us)
    }

    /// Number of queued requests.
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    /// Whether a batch should be released at `now_us`.
    pub fn ready(&self, now_us: u64) -> bool {
        self.lock().ready(now_us)
    }

    /// Earliest time at which [`Self::ready`] will hold without further
    /// admissions.
    pub fn next_deadline_us(&self) -> Option<u64> {
        self.lock().next_deadline_us()
    }

    /// Release the next batch if ready; the batch is moved out under the
    /// lock and owned by the caller (the lock is *not* held while the
    /// batch computes).
    pub fn pop_batch(&self, now_us: u64) -> Option<Vec<Request>> {
        self.lock().pop_batch(now_us)
    }

    /// Unconditionally release the next (possibly partial) batch.
    pub fn drain_batch(&self) -> Vec<Request> {
        self.lock().drain_batch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queue(max_batch: usize, max_wait_us: u64) -> MicroBatchQueue {
        MicroBatchQueue::new(BatchPolicy::new(max_batch, max_wait_us))
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut q = queue(3, 1_000_000);
        for i in 0..3 {
            q.push(vec![i as f32], 10);
        }
        assert!(q.ready(10));
        let batch = q.pop_batch(10).unwrap();
        assert_eq!(batch.len(), 3);
        assert!(q.is_empty());
        // FIFO order and monotone ids.
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut q = queue(8, 500);
        q.push(vec![1.0], 100);
        q.push(vec![2.0], 300);
        assert!(!q.ready(400));
        assert_eq!(q.pop_batch(400).map(|b| b.len()), None);
        // Deadline is oldest arrival + max_wait.
        assert_eq!(q.next_deadline_us(), Some(600));
        assert!(q.ready(600));
        let batch = q.pop_batch(600).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].arrival_us, 100);
    }

    #[test]
    fn oversized_backlog_releases_in_max_batch_chunks() {
        let mut q = queue(4, 0);
        for i in 0..10 {
            q.push(vec![i as f32], 0);
        }
        assert_eq!(q.pop_batch(0).unwrap().len(), 4);
        assert_eq!(q.pop_batch(0).unwrap().len(), 4);
        assert_eq!(q.pop_batch(0).unwrap().len(), 2);
        assert!(q.pop_batch(0).is_none());
    }

    #[test]
    fn empty_queue_never_ready() {
        let q = queue(1, 0);
        assert!(!q.ready(u64::MAX));
        assert_eq!(q.next_deadline_us(), None);
    }

    #[test]
    fn drain_releases_partial_without_deadline() {
        let mut q = queue(8, u64::MAX);
        q.push(vec![0.5], 7);
        assert!(!q.ready(1_000_000));
        let batch = q.drain_batch();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].x, vec![0.5]);
    }

    #[test]
    fn zero_max_batch_clamped_to_one() {
        let mut q = queue(0, 0);
        q.push(vec![1.0], 0);
        assert_eq!(q.pop_batch(0).unwrap().len(), 1);
    }

    /// Policy swaps re-judge already-queued requests: a backlog held by a
    /// long max-wait releases immediately once the policy tightens, and a
    /// shrunk max_batch caps subsequent releases.
    #[test]
    fn set_policy_applies_to_queued_requests() {
        let mut q = queue(8, u64::MAX);
        for i in 0..6 {
            q.push(vec![i as f32], 0);
        }
        assert!(!q.ready(1_000_000));
        q.set_policy(BatchPolicy::new(4, 0));
        assert_eq!(q.policy().max_batch, 4);
        assert!(q.ready(0));
        assert_eq!(q.pop_batch(0).unwrap().len(), 4);
        // Two requests remain, below the cap: a tightened finite wait
        // re-judges the partial batch against the new deadline.
        q.set_policy(BatchPolicy::new(4, 500));
        assert_eq!(q.next_deadline_us(), Some(500));
        assert!(!q.ready(100));
        assert_eq!(q.pop_batch(500).unwrap().len(), 2);
        // The setter re-clamps max_batch to >= 1 like the constructor.
        q.set_policy(BatchPolicy::new(0, 0));
        assert_eq!(q.policy().max_batch, 1);

        let sq = SharedQueue::new(BatchPolicy::new(8, 1_000));
        sq.push(vec![1.0], 0);
        sq.set_policy(BatchPolicy::new(1, 0));
        assert_eq!(sq.policy().max_batch, 1);
        assert_eq!(sq.pop_batch(0).unwrap().len(), 1);
    }

    /// Bounded admission: try_push sheds exactly above capacity with the
    /// typed error, ids are only consumed by admitted requests, popping
    /// frees capacity, and capacity 0 never sheds.
    #[test]
    fn bounded_queue_sheds_with_typed_error() {
        let mut q = MicroBatchQueue::with_capacity(BatchPolicy::new(2, 0), 3);
        assert_eq!(q.capacity(), 3);
        for i in 0..3 {
            assert_eq!(q.try_push(vec![i as f32], 0).unwrap(), i as u64);
        }
        let err = q.try_push(vec![9.0], 0).unwrap_err();
        assert!(matches!(err, DdlError::QueueFull { capacity: 3 }), "got {err}");
        assert_eq!(q.shed_count(), 1);
        assert_eq!(q.len(), 3, "shed sample must not be queued");
        // A shed consumes no id: the next admitted request continues the
        // sequence, keeping batches replayable.
        assert_eq!(q.pop_batch(0).unwrap().len(), 2);
        assert_eq!(q.try_push(vec![4.0], 1).unwrap(), 3);
        assert_eq!(q.shed_count(), 1);
        // The infallible push ignores the bound (legacy admit).
        q.push(vec![5.0], 2);
        q.push(vec![6.0], 2);
        assert_eq!(q.len(), 4);
        // Capacity 0 = unbounded: try_push never sheds.
        let mut un = MicroBatchQueue::new(BatchPolicy::new(1, 0));
        assert_eq!(un.capacity(), 0);
        for i in 0..100 {
            un.try_push(vec![0.0], i).unwrap();
        }
        assert_eq!(un.shed_count(), 0);
    }

    #[test]
    fn shared_queue_mirrors_bounded_admission() {
        let q = SharedQueue::with_capacity(BatchPolicy::new(4, 0), 2);
        assert_eq!(q.capacity(), 2);
        q.try_push(vec![1.0], 0).unwrap();
        q.try_push(vec![2.0], 0).unwrap();
        assert!(matches!(
            q.try_push(vec![3.0], 0).unwrap_err(),
            DdlError::QueueFull { capacity: 2 }
        ));
        assert_eq!(q.shed_count(), 1);
        assert_eq!(q.len(), 2);
        assert_eq!(SharedQueue::new(BatchPolicy::new(1, 0)).capacity(), 0);
    }

    #[test]
    fn shared_queue_concurrent_producers() {
        use std::sync::Arc;
        let q = Arc::new(SharedQueue::new(BatchPolicy::new(4, 0)));
        assert_eq!(q.policy().max_batch, 4);
        let handles: Vec<_> = (0..3)
            .map(|t| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..8 {
                        q.push(vec![(t * 8 + i) as f32], 0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.len(), 24);
        // Ids stayed unique and monotone under concurrency.
        let mut seen = std::collections::BTreeSet::new();
        while let Some(batch) = q.pop_batch(0) {
            for r in batch {
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
            }
        }
        assert!(q.is_empty());
        assert_eq!(seen.len(), 24);
    }

    /// The norm screen: a clean, clustered stream is never quarantined
    /// (the 0.5·median floor defeats the MAD ≈ 0 trap), a gross outlier
    /// is, and an all-poisoned batch still keeps its min-norm sample.
    #[test]
    fn poison_screen_quarantines_outliers_only() {
        let req = |id: u64, x: Vec<f32>| Request { id, arrival_us: 0, x };
        // Tightly clustered honest norms: MAD is tiny, yet nothing may be
        // quarantined (zero false positives on clean streams).
        let clean: Vec<Request> =
            (0..8).map(|i| req(i, vec![1.0 + 0.001 * i as f32, 0.0])).collect();
        let norms: Vec<f64> = clean.iter().map(|r| sample_norm(&r.x)).collect();
        let th = poison_norm_threshold(&norms, 6.0);
        assert!(th >= 1.5, "floor must hold: {th}");
        let (kept, quarantined) = screen_batch(clean, th);
        assert_eq!(kept.len(), 8);
        assert!(quarantined.is_empty());
        // One poisoned sample far above the cluster is quarantined; order
        // is preserved among the kept.
        let mut mixed: Vec<Request> = (0..7).map(|i| req(i, vec![1.0, 0.01 * i as f32])).collect();
        mixed.insert(3, req(99, vec![50.0, -50.0]));
        let norms: Vec<f64> = mixed.iter().map(|r| sample_norm(&r.x)).collect();
        let th = poison_norm_threshold(&norms, 6.0);
        let (kept, quarantined) = screen_batch(mixed, th);
        assert_eq!(quarantined.len(), 1);
        assert_eq!(quarantined[0].id, 99);
        assert_eq!(kept.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4, 5, 6]);
        // Every sample above threshold: the min-norm one survives anyway.
        let storm: Vec<Request> =
            (0..4).map(|i| req(i, vec![40.0 + i as f32, 0.0])).collect();
        let (kept, quarantined) = screen_batch(storm, 1.0);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].id, 0);
        assert_eq!(quarantined.len(), 3);
        // Empty inputs are inert.
        assert!(poison_norm_threshold(&[], 6.0).is_infinite());
        let (kept, quarantined) = screen_batch(Vec::new(), 0.0);
        assert!(kept.is_empty() && quarantined.is_empty());
        assert_eq!(sample_norm(&[3.0, 4.0]), 5.0);
    }

    #[test]
    fn shared_queue_batch_moved_out_of_lock() {
        let q = SharedQueue::new(BatchPolicy::new(2, 1_000));
        q.push(vec![1.0], 0);
        q.push(vec![2.0], 1);
        let batch = q.pop_batch(1).unwrap();
        assert_eq!(batch.len(), 2);
        // The popped batch is caller-owned: the queue is free for
        // admission and inspection while it is "in flight".
        q.push(vec![3.0], 2);
        assert_eq!(q.len(), 1);
        assert_eq!(q.next_deadline_us(), Some(1_002));
        drop(batch);
    }
}
