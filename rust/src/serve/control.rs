//! Feedback control plane for the serving layers: measurement-driven
//! batch size and pipeline depth.
//!
//! PR 2/PR 3 gave the service a micro-batching queue and a concurrent
//! pipeline, both running on hand-tuned static knobs (`batch`,
//! `max_wait_us`, `pipeline_depth`). The sessions already *measure*
//! everything a controller needs — per-request latency, formed batch
//! sizes, per-stage timing — but never feed it back. This module closes
//! those loops:
//!
//! * [`BatchController`] — tracks a sliding window of request latencies
//!   and batch fills and re-decides the [`BatchPolicy`] each control tick
//!   to hit a configured p99-latency SLO while maximizing throughput:
//!   AIMD on `max_wait_us` (halve on SLO violation, gently widen on
//!   comfort — waiting trades latency for batching efficiency) and
//!   fill-driven doubling/halving of `max_batch` (full batches mean
//!   backlog to drain, persistently empty ones mean the cap is slack).
//! * [`DepthController`] — counts, per epoch of batches, how often the
//!   virtual pipeline was *token-starved* (a formed batch had to wait for
//!   a dictionary snapshot, i.e. the swap schedule was the bottleneck)
//!   and re-plans the pipeline depth by at most ±1 at epoch boundaries,
//!   keeping the swap schedule `S_j` deterministic per session.
//! * [`ServiceModel`] + [`PipeSim`] — the virtual µs clocks adaptive
//!   sessions run on. Instead of measured wall time, one batch of `B`
//!   samples costs `svc_base_us + svc_per_sample_us·B` (serial loop /
//!   inference stage) and `upd_per_sample_us·B` (update stage), so every
//!   controller input — and therefore every decision — is a pure function
//!   of (config, seed, arrival stream). Two adaptive runs replay
//!   **bit-identically**: same decision traces, same batch sequence, same
//!   final dictionary (`tests/control_adaptive.rs`).
//!
//! The controllers never see wall-clock time; with the control plane
//! disabled (`[control] enabled = false`, the default) the serve
//! executors take exactly their static PR 3 code paths. The τ controller
//! for the async executor lives in [`crate::net::tau_control`] — same
//! design rules, different substrate.

use crate::config::experiment::ControlConfig;
use crate::math::stats;
use crate::serve::queue::BatchPolicy;
use std::collections::VecDeque;

/// One batch-controller decision, recorded at every control tick so
/// adaptive runs can be audited and replay-checked.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ControlDecision {
    /// Virtual time of the decision (µs).
    pub t_us: u64,
    /// `max_batch` in effect after the decision.
    pub max_batch: usize,
    /// `max_wait_us` in effect after the decision.
    pub max_wait_us: u64,
    /// Window p99 at decision time (ms); −1 when the window was too
    /// small to act on.
    pub p99_ms: f64,
    /// Mean recent batch fill relative to the cap each batch was formed
    /// under, in [0, 1]; −1 when no batch completed yet.
    pub fill: f64,
    /// Requests the bounded admission queue shed since the previous
    /// decision (0 for unbounded queues).
    pub shed: usize,
}

/// One depth-controller re-plan, recorded at epoch boundaries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DepthDecision {
    /// Index of the first batch the new depth applies to.
    pub batch: usize,
    /// Pipeline depth in effect from that batch on.
    pub depth: usize,
    /// Token-starved batches observed in the epoch that triggered the
    /// decision.
    pub starved: usize,
}

/// Deterministic virtual service-time model (see the module docs). The
/// constants come from `[control]`; they stand in for measured wall time
/// whenever a controller is active, which is what makes adaptive runs
/// replay bit-identically.
#[derive(Clone, Copy, Debug)]
pub struct ServiceModel {
    /// Fixed per-batch overhead (µs): thread wake-ups, combine setup.
    pub base_us: u64,
    /// Marginal inference cost per sample in the batch (µs).
    pub per_sample_us: u64,
    /// Eq. 51 update-stage cost per sample (µs), pipeline mode.
    pub upd_per_sample_us: u64,
}

impl ServiceModel {
    /// Model from the `[control]` block.
    pub fn from_config(cfg: &ControlConfig) -> Self {
        ServiceModel {
            base_us: cfg.svc_base_us,
            per_sample_us: cfg.svc_per_sample_us,
            upd_per_sample_us: cfg.upd_per_sample_us,
        }
    }

    /// Virtual cost of one serial service step / one inference sweep over
    /// a batch of `b` samples (µs).
    pub fn service_us(&self, b: usize) -> u64 {
        self.base_us + self.per_sample_us * b as u64
    }

    /// Virtual cost of the Eq. 51 update stage over `b` samples (µs).
    pub fn update_us(&self, b: usize) -> u64 {
        self.upd_per_sample_us * b as u64
    }
}

/// Fits the affine service law `base_us + per_sample_us · B` from the
/// first `target` measured `(batch size, wall service µs)` pairs of a
/// session, then freezes ([`ControlConfig::calibrate`]).
///
/// The replay contract: the fit is an exact least-squares solve over the
/// recorded samples with one deterministic integer rounding at the end —
/// a pure function of the sample sequence. Two sessions that observe the
/// same `(B, µs)` pairs therefore drive the identical frozen model and
/// take the identical control decisions thereafter; what calibration
/// trades away is only *cross-machine* bit-replay, because the samples
/// themselves come from this machine's wall clock. Until the freeze the
/// configured model stays in force, so the virtual clock never consumes a
/// raw wall measurement directly.
#[derive(Clone, Debug)]
pub struct ServiceCalibrator {
    /// Configured model: drives the clock pre-freeze, donates
    /// `upd_per_sample_us` (not observable from serial service times) and
    /// the slope fallback for degenerate (constant-B) sample sets.
    configured: ServiceModel,
    samples: Vec<(usize, u64)>,
    target: usize,
    fitted: Option<ServiceModel>,
}

impl ServiceCalibrator {
    /// Calibrator that freezes after `cfg.calib_batches` observations.
    pub fn from_config(cfg: &ControlConfig) -> Self {
        ServiceCalibrator {
            configured: ServiceModel::from_config(cfg),
            samples: Vec::with_capacity(cfg.calib_batches),
            target: cfg.calib_batches.max(2),
            fitted: None,
        }
    }

    /// Record one measured batch. Returns `true` exactly once, on the
    /// observation that completes the sample set and freezes the fit;
    /// observations after the freeze are ignored.
    pub fn observe(&mut self, batch: usize, measured_us: u64) -> bool {
        if self.fitted.is_some() {
            return false;
        }
        self.samples.push((batch, measured_us));
        if self.samples.len() < self.target {
            return false;
        }
        self.fitted = Some(self.fit());
        true
    }

    /// The model currently in force: configured until the freeze, fitted
    /// after.
    pub fn model(&self) -> ServiceModel {
        self.fitted.unwrap_or(self.configured)
    }

    /// Whether the fit has frozen.
    pub fn is_frozen(&self) -> bool {
        self.fitted.is_some()
    }

    /// Exact least squares of `µs ~ base + slope · B` over the recorded
    /// samples; slope and intercept are clamped non-negative and rounded
    /// half-up to whole µs so the frozen model is integer-for-integer
    /// reproducible from the sample sequence.
    fn fit(&self) -> ServiceModel {
        let n = self.samples.len() as f64;
        let mean_b = self.samples.iter().map(|&(b, _)| b as f64).sum::<f64>() / n;
        let mean_y = self.samples.iter().map(|&(_, y)| y as f64).sum::<f64>() / n;
        let mut var = 0.0;
        let mut cov = 0.0;
        for &(b, y) in &self.samples {
            let db = b as f64 - mean_b;
            var += db * db;
            cov += db * (y as f64 - mean_y);
        }
        let slope = if var > f64::EPSILON {
            (cov / var).max(0.0)
        } else {
            // Every batch had the same size: the slope is unidentifiable,
            // keep the configured marginal cost and fit the offset only.
            self.configured.per_sample_us as f64
        };
        let base = (mean_y - slope * mean_b).max(0.0);
        ServiceModel {
            base_us: (base + 0.5).floor() as u64,
            per_sample_us: (slope + 0.5).floor() as u64,
            upd_per_sample_us: self.configured.upd_per_sample_us,
        }
    }
}

/// Clamp a static `(max_batch, max_wait_us)` pair into the controller's
/// bounds — the initial policy of an adaptive session (and the whole
/// policy, when the bounds are pinned to a single point). Inverted
/// bounds are repaired to `min ≤ max` (matching the TOML sanitization)
/// rather than panicking.
pub fn clamped_policy(cfg: &ControlConfig, max_batch: usize, max_wait_us: u64) -> BatchPolicy {
    let b_lo = cfg.batch_min.max(1);
    let w_lo = cfg.wait_min_us;
    BatchPolicy::new(
        max_batch.clamp(b_lo, cfg.batch_max.max(b_lo)),
        max_wait_us.clamp(w_lo, cfg.wait_max_us.max(w_lo)),
    )
}

/// Measurement-driven batch-formation controller (see the module docs
/// for the law). Decisions are taken at most once per `tick_us` of
/// virtual time and recorded in the decision trace.
pub struct BatchController {
    slo_p99_ms: f64,
    tick_us: u64,
    batch_min: usize,
    batch_max: usize,
    wait_min_us: u64,
    wait_max_us: u64,
    window: usize,
    policy: BatchPolicy,
    /// Completed-request latencies (ms), newest last, trimmed to
    /// `window`.
    latencies_ms: VecDeque<f64>,
    /// Recent batch fills `b / max_batch` (relative to the cap in effect
    /// when observed), trimmed to 8.
    fills: VecDeque<f64>,
    next_tick_us: u64,
    /// Load shed by the bounded admission queue since the last decision.
    shed_since_tick: usize,
    decisions: Vec<ControlDecision>,
}

/// Minimum window occupancy before the p99 estimate is acted on.
const MIN_P99_SAMPLES: usize = 16;
/// Fills at or above this fraction of the cap read as backlog pressure.
const FILL_HI: f64 = 0.9;
/// Fills below this fraction read as a slack cap.
const FILL_LO: f64 = 0.25;

impl BatchController {
    /// Controller starting from `(max_batch, max_wait_us)` clamped into
    /// the configured bounds.
    pub fn new(cfg: &ControlConfig, max_batch: usize, max_wait_us: u64) -> Self {
        BatchController {
            slo_p99_ms: cfg.slo_p99_ms,
            tick_us: cfg.tick_us.max(1),
            batch_min: cfg.batch_min.max(1),
            batch_max: cfg.batch_max.max(cfg.batch_min.max(1)),
            wait_min_us: cfg.wait_min_us,
            wait_max_us: cfg.wait_max_us.max(cfg.wait_min_us),
            // A window below the actionable-p99 floor would silently
            // disable the SLO law (the estimate would never be acted
            // on) — clamp it up instead.
            window: cfg.window.max(MIN_P99_SAMPLES),
            policy: clamped_policy(cfg, max_batch, max_wait_us),
            latencies_ms: VecDeque::new(),
            fills: VecDeque::new(),
            next_tick_us: cfg.tick_us.max(1),
            shed_since_tick: 0,
            decisions: Vec::new(),
        }
    }

    /// The policy currently in effect.
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Feed one completed batch: its size, the `max_batch` cap the batch
    /// was actually *formed under* (in the pipeline a fresh decision only
    /// reaches the queue when its token is consumed, so in-flight batches
    /// may predate the current policy), and its requests' latencies (ms,
    /// on the virtual clock).
    pub fn observe_batch(&mut self, batch_size: usize, formed_cap: usize, latencies_ms: &[f64]) {
        self.fills.push_back(batch_size as f64 / formed_cap.max(1) as f64);
        while self.fills.len() > 8 {
            self.fills.pop_front();
        }
        for &l in latencies_ms {
            self.latencies_ms.push_back(l);
        }
        while self.latencies_ms.len() > self.window {
            self.latencies_ms.pop_front();
        }
    }

    /// Report `n` requests shed by the bounded admission queue
    /// ([`crate::error::DdlError::QueueFull`]). Sheds are the strongest
    /// overload signal the controller sees — demand the queue could not
    /// even hold — and they override the fill/SLO laws at the next tick.
    pub fn observe_shed(&mut self, n: usize) {
        self.shed_since_tick += n;
    }

    /// Re-decide the policy if a control tick has elapsed by `now_us`;
    /// returns the (possibly unchanged) policy to install when a decision
    /// was taken. Pure function of the observations fed so far.
    pub fn maybe_decide(&mut self, now_us: u64) -> Option<BatchPolicy> {
        if now_us < self.next_tick_us {
            return None;
        }
        while self.next_tick_us <= now_us {
            self.next_tick_us += self.tick_us;
        }
        let p99 = if self.latencies_ms.len() >= MIN_P99_SAMPLES {
            // One copy out of the ring, sorted in place — no second
            // allocation (the point of the sort-once helpers).
            let mut v: Vec<f64> = self.latencies_ms.iter().copied().collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            Some(stats::percentile_sorted(&v, 99.0))
        } else {
            None
        };
        let fill = if self.fills.is_empty() {
            None
        } else {
            Some(self.fills.iter().sum::<f64>() / self.fills.len() as f64)
        };
        let mut b = self.policy.max_batch;
        let mut w = self.policy.max_wait_us;
        if let Some(f) = fill {
            if f >= FILL_HI {
                // Backlog pressure: bigger batches amortize the per-batch
                // overhead and drain bursts faster (throughput *and*
                // tail latency improve together under backlog).
                b = (b * 2).min(self.batch_max);
            } else if f < FILL_LO && b > self.batch_min {
                // Cap far above realized batches: decay it so a later
                // burst starts from a cap that tracks the load.
                b = (b / 2).max(self.batch_min);
            }
        }
        if let Some(p) = p99 {
            if p > self.slo_p99_ms {
                // SLO violated and batches are not full: the wait budget
                // is the latency we are paying — cut it multiplicatively.
                w = (w / 2).max(self.wait_min_us);
            } else if p <= 0.5 * self.slo_p99_ms {
                // Comfortable margin: widen the wait budget gently to buy
                // batching efficiency (additive floor so 0 can recover).
                w = (w + w / 2 + 64).min(self.wait_max_us);
            }
        }
        let shed = self.shed_since_tick;
        self.shed_since_tick = 0;
        if shed > 0 {
            // Overflow storm: the queue rejected demand outright. Drain
            // harder than either steady-state law would — widen the cap
            // for throughput and cut the wait budget so formed batches
            // release immediately.
            b = (self.policy.max_batch * 2).min(self.batch_max);
            w = (self.policy.max_wait_us / 2).max(self.wait_min_us);
        }
        self.policy = BatchPolicy::new(b, w);
        self.decisions.push(ControlDecision {
            t_us: now_us,
            max_batch: self.policy.max_batch,
            max_wait_us: self.policy.max_wait_us,
            p99_ms: p99.unwrap_or(-1.0),
            fill: fill.unwrap_or(-1.0),
            shed,
        });
        Some(self.policy)
    }

    /// The decision trace so far.
    pub fn decisions(&self) -> &[ControlDecision] {
        &self.decisions
    }

    /// Tear down, keeping the decision trace.
    pub fn into_decisions(self) -> Vec<ControlDecision> {
        self.decisions
    }
}

/// Epoch-boundary pipeline-depth controller. `observe` is fed one flag
/// per batch (was the virtual pipeline token-starved for it?);
/// `maybe_replan` is consulted after every batch and moves the depth by
/// at most ±1 when a batch epoch completes.
pub struct DepthController {
    depth_min: usize,
    depth_max: usize,
    epoch_batches: usize,
    depth: usize,
    starved_in_epoch: usize,
    seen_in_epoch: usize,
    decisions: Vec<DepthDecision>,
}

impl DepthController {
    /// Controller starting from `initial` clamped into the configured
    /// bounds.
    pub fn new(cfg: &ControlConfig, initial: usize) -> Self {
        let depth_min = cfg.depth_min.max(1);
        let depth_max = cfg.depth_max.max(depth_min);
        DepthController {
            depth_min,
            depth_max,
            epoch_batches: cfg.epoch_batches.max(1),
            depth: initial.clamp(depth_min, depth_max),
            starved_in_epoch: 0,
            seen_in_epoch: 0,
            decisions: Vec::new(),
        }
    }

    /// Depth currently in effect.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Feed one processed batch's starvation flag.
    pub fn observe(&mut self, token_starved: bool) {
        self.seen_in_epoch += 1;
        if token_starved {
            self.starved_in_epoch += 1;
        }
    }

    /// Re-plan at the epoch boundary following batch `batch_idx`
    /// (0-based). Returns the depth delta to apply (−1, 0, +1); the
    /// caller realizes it by injecting or withholding one snapshot token.
    pub fn maybe_replan(&mut self, batch_idx: usize) -> i32 {
        if (batch_idx + 1) % self.epoch_batches != 0 || self.seen_in_epoch == 0 {
            return 0;
        }
        let starved = self.starved_in_epoch;
        let seen = self.seen_in_epoch;
        self.starved_in_epoch = 0;
        self.seen_in_epoch = 0;
        let delta = if starved * 2 >= seen && self.depth < self.depth_max {
            // The swap schedule throttled at least half the epoch:
            // trade one more batch of staleness for overlap.
            1
        } else if starved == 0 && self.depth > self.depth_min {
            // Tokens never bound: the extra staleness buys nothing.
            -1
        } else {
            0
        };
        if delta != 0 {
            self.depth = (self.depth as i64 + delta as i64) as usize;
            self.decisions.push(DepthDecision { batch: batch_idx + 1, depth: self.depth, starved });
        }
        delta
    }

    /// The re-plan trace so far.
    pub fn decisions(&self) -> &[DepthDecision] {
        &self.decisions
    }

    /// Tear down, keeping the re-plan trace.
    pub fn into_decisions(self) -> Vec<DepthDecision> {
        self.decisions
    }
}

/// Virtual timing of the three-stage pipeline (formation | inference |
/// update) under the [`ServiceModel`]: a deterministic recurrence the
/// updater advances in batch order. Tokens mirror the snapshot tokens of
/// the real executors — `tokens[i]` is the virtual time the `i`-th
/// outstanding snapshot became available — so "token-starved" below means
/// the swap schedule, not compute, throttled a batch.
pub struct PipeSim {
    model: ServiceModel,
    /// Inference-slot free times (slot = batch index mod slots).
    slot_free_us: Vec<u64>,
    /// Update-stage free time (the updater is a single serial stage).
    upd_free_us: u64,
    /// Publish time of the batch most recently fed to [`Self::batch`]:
    /// when the updater picks the batch up and swaps the double buffer —
    /// *before* paying the Eq. 51 update cost, mirroring the real
    /// executors' publish-before-update order (a depth-1 pipeline
    /// genuinely overlaps `U_j` with the next batch's inference).
    publish_us: u64,
    /// Availability times of outstanding snapshot tokens, FIFO.
    tokens: VecDeque<u64>,
    /// Convergence freeze ([`crate::learn::ConvergenceDetector`]): while
    /// set, batches skip the Eq. 51 update, so the update stage charges
    /// nothing — the virtual-clock form of "the updater slot is released
    /// to pure inference".
    frozen: bool,
}

impl PipeSim {
    /// Simulator with `slots` inference slots and `prefill` snapshot
    /// tokens available at t = 0 (the initial pipeline depth).
    pub fn new(model: ServiceModel, slots: usize, prefill: usize) -> Self {
        PipeSim {
            model,
            slot_free_us: vec![0; slots.max(1)],
            upd_free_us: 0,
            publish_us: 0,
            tokens: (0..prefill).map(|_| 0).collect(),
            frozen: false,
        }
    }

    /// Set the convergence-freeze state for subsequent batches. The updater
    /// calls this with the detector's verdict before charging each batch,
    /// so freeze/thaw boundaries land exactly on batch boundaries in the
    /// virtual timeline too.
    pub fn set_frozen(&mut self, frozen: bool) {
        self.frozen = frozen;
    }

    /// Advance the recurrence for batch `j` of size `b`, formed at
    /// `formed_us` on the formation clock. Returns `(completion_us,
    /// token_starved)`: the virtual inference-completion time (requests
    /// are servable then; latency is measured against it) and whether the
    /// snapshot token was the binding constraint on the batch's start.
    pub fn batch(&mut self, j: usize, formed_us: u64, b: usize) -> (u64, bool) {
        let avail = self.tokens.pop_front().expect("pipe sim: token schedule invariant");
        let slot = j % self.slot_free_us.len();
        let free = self.slot_free_us[slot];
        let start = formed_us.max(avail).max(free);
        let starved = avail > formed_us && avail > free;
        let done = start + self.model.service_us(b);
        self.slot_free_us[slot] = done;
        // The updater publishes (token-ready point) when it picks the
        // batch up, then pays the update cost — zero while a convergence
        // freeze is in effect (the Eq. 51 update is skipped).
        self.publish_us = done.max(self.upd_free_us);
        let upd = if self.frozen { 0 } else { self.model.update_us(b) };
        self.upd_free_us = self.publish_us + upd;
        (done, starved)
    }

    /// Record `count` snapshot tokens emitted at the current batch's
    /// publish point (before its Eq. 51 update cost — see
    /// [`Self::batch`]).
    pub fn emit_tokens(&mut self, count: usize) {
        for _ in 0..count {
            self.tokens.push_back(self.publish_us);
        }
    }

    /// Virtual session clock: everything processed so far (inference and
    /// updates) has finished by this time.
    pub fn now_us(&self) -> u64 {
        self.upd_free_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ControlConfig {
        ControlConfig {
            enabled: true,
            slo_p99_ms: 10.0,
            tick_us: 1_000,
            batch_min: 1,
            batch_max: 32,
            wait_min_us: 0,
            wait_max_us: 8_000,
            window: 64,
            ..ControlConfig::default()
        }
    }

    /// Samples drawn from an exact affine law are recovered exactly, and
    /// the same sample sequence always freezes the identical model — the
    /// replay contract of `[control] calibrate`.
    #[test]
    fn calibrator_recovers_affine_law_and_replays() {
        let c = ControlConfig { calib_batches: 6, upd_per_sample_us: 60, ..cfg() };
        let feed = |cal: &mut ServiceCalibrator| {
            let mut frozen_at = None;
            for (i, b) in [1usize, 4, 2, 8, 3, 6].iter().enumerate() {
                if cal.observe(*b, 120 + 35 * *b as u64) {
                    frozen_at = Some(i);
                }
            }
            frozen_at
        };
        let mut cal = ServiceCalibrator::from_config(&c);
        assert!(!cal.is_frozen());
        assert_eq!(feed(&mut cal), Some(5), "freeze fires exactly on the K-th sample");
        let m = cal.model();
        assert_eq!((m.base_us, m.per_sample_us), (120, 35));
        assert_eq!(m.upd_per_sample_us, 60, "update cost carries over from the config");
        // Replay: an independent calibrator over the same samples lands on
        // the integer-identical model.
        let mut replay = ServiceCalibrator::from_config(&c);
        feed(&mut replay);
        let r = replay.model();
        assert_eq!((r.base_us, r.per_sample_us, r.upd_per_sample_us), (120, 35, 60));
        // Post-freeze observations are ignored: the model never re-fits.
        assert!(!cal.observe(64, 1_000_000));
        let after = cal.model();
        assert_eq!((after.base_us, after.per_sample_us), (120, 35));
    }

    /// Constant batch sizes leave the slope unidentifiable: the configured
    /// marginal cost is kept and only the offset is fitted.
    #[test]
    fn calibrator_constant_batches_fit_offset_only() {
        let c = ControlConfig {
            calib_batches: 4,
            svc_per_sample_us: 150,
            ..cfg()
        };
        let mut cal = ServiceCalibrator::from_config(&c);
        for _ in 0..4 {
            cal.observe(4, 1_000);
        }
        let m = cal.model();
        assert_eq!(m.per_sample_us, 150);
        // base = mean(1000) − 150·4 = 400.
        assert_eq!(m.base_us, 400);
    }

    /// Pre-freeze the configured model stays in force, so the virtual
    /// clock never consumes a raw wall measurement.
    #[test]
    fn calibrator_serves_configured_model_until_frozen() {
        let c = ControlConfig { calib_batches: 3, ..cfg() };
        let mut cal = ServiceCalibrator::from_config(&c);
        let configured = ServiceModel::from_config(&c);
        cal.observe(2, 999_999);
        assert!(!cal.is_frozen());
        assert_eq!(cal.model().service_us(5), configured.service_us(5));
    }

    #[test]
    fn initial_policy_clamped_into_bounds() {
        let c = ControlConfig { batch_min: 4, batch_max: 16, wait_min_us: 100, ..cfg() };
        let ctl = BatchController::new(&c, 64, 0);
        assert_eq!(ctl.policy().max_batch, 16);
        assert_eq!(ctl.policy().max_wait_us, 100);
        assert_eq!(clamped_policy(&c, 1, 1_000_000).max_batch, 4);
        assert_eq!(clamped_policy(&c, 1, 1_000_000).max_wait_us, c.wait_max_us);
    }

    #[test]
    fn violation_halves_wait_and_comfort_widens_it() {
        let mut ctl = BatchController::new(&cfg(), 8, 4_000);
        // p99 well above the 10 ms SLO.
        ctl.observe_batch(2, 8, &[15.0; 32]);
        let p = ctl.maybe_decide(1_000).expect("tick due");
        assert_eq!(p.max_wait_us, 2_000);
        // Comfortable latencies: wait creeps back up.
        ctl.observe_batch(2, 8, &[1.0; 64]);
        let p = ctl.maybe_decide(2_000).expect("tick due");
        assert!(p.max_wait_us > 2_000, "comfort should widen the wait budget");
        assert_eq!(ctl.decisions().len(), 2);
        assert!(ctl.decisions()[0].p99_ms > 10.0);
    }

    #[test]
    fn backlog_doubles_batch_and_slack_decays_it() {
        let mut ctl = BatchController::new(&cfg(), 8, 1_000);
        // Full batches, healthy latency: cap doubles.
        ctl.observe_batch(8, 8, &[1.0; 32]);
        assert_eq!(ctl.maybe_decide(1_000).unwrap().max_batch, 16);
        // Tiny batches (formed under the new cap) for a while: cap decays.
        for _ in 0..8 {
            ctl.observe_batch(1, 16, &[1.0; 4]);
        }
        assert_eq!(ctl.maybe_decide(2_000).unwrap().max_batch, 8);
    }

    /// Sheds override the steady-state laws at the next tick: the cap
    /// doubles and the wait halves, then the counter resets so a calm
    /// tick returns to the normal laws.
    #[test]
    fn shed_overrides_fill_and_slo_laws() {
        let mut ctl = BatchController::new(&cfg(), 8, 4_000);
        // Slack fill would normally decay the cap; the shed wins.
        for _ in 0..8 {
            ctl.observe_batch(1, 8, &[1.0; 4]);
        }
        ctl.observe_shed(3);
        let p = ctl.maybe_decide(1_000).expect("tick due");
        assert_eq!(p.max_batch, 16, "shed must widen the cap despite slack fill");
        assert_eq!(p.max_wait_us, 2_000, "shed must cut the wait budget");
        assert_eq!(ctl.decisions()[0].shed, 3);
        // Next tick with no sheds: back to the steady-state laws (slack
        // fill decays the cap again).
        for _ in 0..8 {
            ctl.observe_batch(1, 16, &[1.0; 4]);
        }
        let p = ctl.maybe_decide(2_000).expect("tick due");
        assert_eq!(p.max_batch, 8);
        assert_eq!(ctl.decisions()[1].shed, 0);
    }

    #[test]
    fn decisions_only_on_ticks() {
        let mut ctl = BatchController::new(&cfg(), 8, 1_000);
        assert!(ctl.maybe_decide(999).is_none());
        assert!(ctl.maybe_decide(1_000).is_some());
        // The tick was consumed; the next decision waits for the next one.
        assert!(ctl.maybe_decide(1_500).is_none());
        assert!(ctl.maybe_decide(2_400).is_some());
        assert_eq!(ctl.decisions().len(), 2);
    }

    /// A `window` below the actionable-p99 floor is clamped up — it must
    /// not silently disable the SLO law.
    #[test]
    fn tiny_window_cannot_disable_slo_steering() {
        let c = ControlConfig { window: 4, ..cfg() };
        let mut ctl = BatchController::new(&c, 8, 4_000);
        ctl.observe_batch(2, 8, &[15.0; 16]);
        let p = ctl.maybe_decide(1_000).expect("tick due");
        assert_eq!(p.max_wait_us, 2_000, "p99 steering must stay live with window = 4");
    }

    #[test]
    fn too_small_window_does_not_touch_wait() {
        let mut ctl = BatchController::new(&cfg(), 8, 1_000);
        ctl.observe_batch(1, 8, &[100.0; 4]); // 4 < MIN_P99_SAMPLES
        let p = ctl.maybe_decide(1_000).unwrap();
        assert_eq!(p.max_wait_us, 1_000);
        assert_eq!(ctl.decisions()[0].p99_ms, -1.0);
    }

    #[test]
    fn depth_replans_by_at_most_one_at_epoch_boundaries() {
        let c = ControlConfig { depth_min: 1, depth_max: 4, epoch_batches: 4, ..cfg() };
        let mut d = DepthController::new(&c, 2);
        assert_eq!(d.depth(), 2);
        // Epoch 0: all starved -> +1.
        for i in 0..4 {
            d.observe(true);
            let delta = d.maybe_replan(i);
            if i < 3 {
                assert_eq!(delta, 0, "no mid-epoch re-plan");
            } else {
                assert_eq!(delta, 1);
            }
        }
        assert_eq!(d.depth(), 3);
        // Epoch 1: never starved -> -1.
        for i in 4..8 {
            d.observe(false);
            d.maybe_replan(i);
        }
        assert_eq!(d.depth(), 2);
        // Epoch 2: half starved -> +1 again (majority rule is >= half).
        for i in 8..12 {
            d.observe(i % 2 == 0);
            d.maybe_replan(i);
        }
        assert_eq!(d.depth(), 3);
        assert_eq!(d.decisions().len(), 3);
        assert_eq!(d.decisions()[0], DepthDecision { batch: 4, depth: 3, starved: 4 });
    }

    #[test]
    fn depth_respects_bounds() {
        let c = ControlConfig { depth_min: 1, depth_max: 2, epoch_batches: 1, ..cfg() };
        let mut d = DepthController::new(&c, 9);
        assert_eq!(d.depth(), 2, "initial depth clamped");
        d.observe(true);
        assert_eq!(d.maybe_replan(0), 0, "already at depth_max");
        let mut d = DepthController::new(&c, 1);
        d.observe(false);
        assert_eq!(d.maybe_replan(0), 0, "already at depth_min");
    }

    #[test]
    fn pipe_sim_depth_bounds_overlap() {
        let model = ServiceModel { base_us: 100, per_sample_us: 0, upd_per_sample_us: 0 };
        // Depth 1, everything formed at t = 0: batches serialize on the
        // single outstanding token (inference j waits for update j-1).
        let mut sim = PipeSim::new(model, 4, 1);
        let (c0, s0) = sim.batch(0, 0, 4);
        sim.emit_tokens(1);
        let (c1, s1) = sim.batch(1, 0, 4);
        sim.emit_tokens(1);
        assert_eq!((c0, s0), (100, false));
        assert_eq!((c1, s1), (200, true), "token must gate batch 1 at depth 1");
        // Depth 2: batch 1 overlaps batch 0 on its own slot.
        let mut sim = PipeSim::new(model, 4, 2);
        let (c0, _) = sim.batch(0, 0, 4);
        sim.emit_tokens(1);
        let (c1, starved) = sim.batch(1, 0, 4);
        assert_eq!(c0, 100);
        assert_eq!(c1, 100, "depth 2 runs batches 0 and 1 concurrently");
        assert!(!starved);
    }

    #[test]
    fn pipe_sim_update_stage_serializes() {
        let model = ServiceModel { base_us: 10, per_sample_us: 0, upd_per_sample_us: 25 };
        let mut sim = PipeSim::new(model, 2, 2);
        sim.batch(0, 0, 4); // infer done 10, update 10..110
        sim.emit_tokens(1);
        sim.batch(1, 0, 4); // infer done 10, update 110..210
        sim.emit_tokens(1);
        assert_eq!(sim.now_us(), 210, "updates are one serial stage");
    }

    /// Tokens become available at the *publish* point (before the Eq. 51
    /// update cost), mirroring the real executors' publish-before-update
    /// order: a depth-1 pipeline overlaps update `j` with inference
    /// `j+1` instead of serializing behind it.
    #[test]
    fn pipe_sim_tokens_ready_at_publish_not_after_update() {
        let model = ServiceModel { base_us: 10, per_sample_us: 0, upd_per_sample_us: 25 };
        let mut sim = PipeSim::new(model, 2, 1); // depth 1
        let (c0, _) = sim.batch(0, 0, 4); // done 10, publish 10, update 10..110
        sim.emit_tokens(1);
        let (c1, starved) = sim.batch(1, 0, 4);
        assert_eq!(c0, 10);
        assert_eq!(c1, 20, "batch 1 starts at the publish point (10), not after the update");
        assert!(starved, "depth 1 still gates on the token itself");
        // Batch 1's update serializes behind batch 0's: 110..210.
        assert_eq!(sim.now_us(), 210);
    }

    /// A convergence freeze zeroes the update-stage charge: the virtual
    /// session clock stops paying `upd_per_sample_us` while frozen and
    /// resumes charging after a thaw — the timing half of "the updater slot
    /// is released to pure inference".
    #[test]
    fn pipe_sim_frozen_batches_skip_update_charge() {
        let model = ServiceModel { base_us: 10, per_sample_us: 0, upd_per_sample_us: 25 };
        let mut sim = PipeSim::new(model, 2, 2);
        sim.batch(0, 0, 4); // adapting: update 10..110
        sim.emit_tokens(1);
        sim.set_frozen(true);
        let (c1, _) = sim.batch(1, 0, 4); // frozen: publish at 110, no update cost
        sim.emit_tokens(1);
        assert_eq!(c1, 20, "inference timing is untouched by the freeze");
        assert_eq!(sim.now_us(), 110, "frozen batch adds zero update time");
        // Thaw: charging resumes at the next batch boundary.
        sim.set_frozen(false);
        sim.batch(2, 0, 4); // done 30, publish 110, update 110..210
        sim.emit_tokens(1);
        assert_eq!(sim.now_us(), 210);
        // An identical always-adapting run pays 3 updates (ends at 310), so
        // the frozen session's virtual clock is strictly ahead.
        let mut always = PipeSim::new(model, 2, 2);
        for j in 0..3 {
            always.batch(j, 0, 4);
            always.emit_tokens(1);
        }
        assert_eq!(always.now_us(), 310);
    }
}
