//! Streaming inference session: the service loop that interleaves batched
//! dual inference with online dictionary adaptation (paper Alg. 1 — each
//! sample is presented to the network exactly once).
//!
//! The loop is a single-server discrete-event simulation driven by a
//! microsecond virtual clock: request arrivals follow the configured rate
//! (Poisson interarrivals, or all-at-once in saturated mode for peak
//! throughput), the [`MicroBatchQueue`] forms minibatches by the
//! max-size/max-wait policy, and each released batch is *processed for
//! real* — one [`crate::learn::OnlineTrainer::step`] over the batched
//! engine, wall-clock timed — before the virtual clock advances by the
//! measured service time. Per-request latency (queueing + service) and
//! end-to-end throughput therefore reflect genuine compute on this
//! machine while arrival timing stays reproducible.
//!
//! Traffic is accounted the way the BSP executor would ship it: one ψ
//! message per directed edge per diffusion iteration, with the batched
//! payload of `B·M` floats (the whole minibatch diffuses in one sweep).

use crate::config::experiment::ServeConfig;
use crate::error::{DdlError, Result};
use crate::graph::{metropolis_csr, metropolis_weights, Graph, Topology};
use crate::infer::{DiffusionEngine, DiffusionParams};
use crate::learn::{ConvEvent, ConvergenceDetector, OnlineTrainer, TrainerOptions};
use crate::math::stats;
use crate::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use crate::net::MessageStats;
use crate::ops::prox::DictProx;
use crate::rng::Pcg64;
use crate::serve::control::{
    BatchController, ControlDecision, DepthDecision, ServiceCalibrator, ServiceModel,
};
use crate::serve::queue::{BatchPolicy, MicroBatchQueue};
use std::time::Instant;

/// Outcome of one streaming session.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Executor that produced the report: `"serial"`, `"pipelined"`, or
    /// `"pipelined-reference"`, with an `-adaptive` infix/suffix when the
    /// control plane drove the session (`"serial-adaptive"`,
    /// `"pipelined-adaptive"`, `"pipelined-adaptive-reference"`).
    pub mode: &'static str,
    /// Batches in flight in the inference stage (`0` for the serial
    /// single-server loop).
    pub pipeline_depth: usize,
    /// Requests served.
    pub samples: usize,
    /// Minibatches drained through the engine.
    pub batches: usize,
    /// Requests shed by the bounded admission queue (`[serve]
    /// queue_capacity`; always 0 when the queue is unbounded).
    pub shed: usize,
    /// Samples quarantined by the poisoned-sample norm screen before
    /// reaching the Eq. 51 update (`--poison`; always 0 with the screen
    /// off). Quarantined samples are not served and pay no latency entry.
    pub quarantined: usize,
    /// Mean formed batch size.
    pub mean_batch: f64,
    /// Virtual session duration (arrival waits + measured service time).
    pub duration_s: f64,
    /// Served samples per second of session time.
    pub throughput_rps: f64,
    /// Request latency percentiles (admission → batch completion), ms.
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub latency_max_ms: f64,
    /// Mean representation loss over the first / last quarter of batches
    /// (the gap shows the dictionary adapting online while serving).
    pub loss_first_quarter: f64,
    pub loss_last_quarter: f64,
    /// Simulated network traffic (ψ exchanges along graph edges).
    pub stats: MessageStats,
    /// Combine path the engine selected (`uniform`/`sparse`/`dense`).
    pub combine_path: &'static str,
    /// Whether the control plane drove this session (`--adaptive`).
    pub adaptive: bool,
    /// p99-latency SLO the batch controller steered to (ms; the
    /// configured value, reported even for static sessions).
    pub slo_p99_ms: f64,
    /// Fraction of requests whose latency exceeded the SLO.
    pub slo_violation_frac: f64,
    /// Batch-controller decision trace (empty for static sessions).
    ///
    /// **Deprecated alias** (kept for one release): the same decisions
    /// are emitted as `batch_policy` instants on the `batch` controller
    /// lane of the trace (`--trace` / `[obs]`), which is the supported
    /// way to capture them going forward.
    pub decisions: Vec<ControlDecision>,
    /// Depth-controller re-plan trace (empty unless adaptive pipeline).
    ///
    /// **Deprecated alias** (kept for one release): re-plans are emitted
    /// as `depth_replan` instants on the `depth` controller lane of the
    /// trace (`--trace` / `[obs]`).
    pub depth_trace: Vec<DepthDecision>,
    /// Convergence-detector trace (empty unless `[convergence] tol > 0`):
    /// drift measurements and freeze/thaw decisions in batch order. The
    /// same events appear as `drift_norm`/`freeze`/`thaw` instants on the
    /// `conv` controller lane of the trace.
    pub conv_events: Vec<ConvEvent>,
    /// Batches served inference-only under a convergence freeze.
    pub frozen_batches: usize,
}

impl ServeReport {
    /// Multi-line human-readable summary.
    pub fn summary(&self, agents: usize) -> String {
        let mut out = self.summary_base(agents);
        if self.adaptive {
            let last = self.decisions.last();
            out.push_str(&format!(
                "\ncontrol: {} decisions, final policy B<={} wait {}µs, {} depth re-plans, \
                 SLO p99 {:.1} ms violated by {:.2}% of requests",
                self.decisions.len(),
                last.map(|d| d.max_batch).unwrap_or(0),
                last.map(|d| d.max_wait_us).unwrap_or(0),
                self.depth_trace.len(),
                self.slo_p99_ms,
                100.0 * self.slo_violation_frac,
            ));
        }
        if self.quarantined > 0 {
            out.push_str(&format!(
                "\npoison screen: {} samples quarantined before the dictionary update",
                self.quarantined,
            ));
        }
        if !self.conv_events.is_empty() || self.frozen_batches > 0 {
            let freezes =
                self.conv_events.iter().filter(|e| matches!(e, ConvEvent::Freeze { .. })).count();
            let thaws =
                self.conv_events.iter().filter(|e| matches!(e, ConvEvent::Thaw { .. })).count();
            out.push_str(&format!(
                "\nconvergence: {} freezes, {} thaws, {} of {} batches served frozen",
                freezes, thaws, self.frozen_batches, self.batches,
            ));
        }
        out
    }

    fn summary_base(&self, agents: usize) -> String {
        format!(
            "[{}] served {} samples in {} batches (mean B = {:.2}, {} shed) over {:.3} s\n\
             throughput: {:.1} samples/s\n\
             latency ms: p50 {:.2}, p95 {:.2}, p99 {:.2}, max {:.2}\n\
             loss: first quarter {:.4} -> last quarter {:.4}\n\
             traffic: {} msgs, {:.2} MB, {} rounds, {:.1} B/agent/round",
            self.mode,
            self.samples,
            self.batches,
            self.mean_batch,
            self.shed,
            self.duration_s,
            self.throughput_rps,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_p99_ms,
            self.latency_max_ms,
            self.loss_first_quarter,
            self.loss_last_quarter,
            self.stats.messages,
            self.stats.bytes as f64 / 1e6,
            self.stats.rounds,
            self.stats.bytes_per_agent_round(agents),
        )
    }
}

/// Build the service topology named by the config.
pub fn build_topology(cfg: &ServeConfig, rng: &mut Pcg64) -> Result<(Graph, Topology)> {
    let topo = match cfg.topology.as_str() {
        "ring" => Topology::Ring { k: cfg.ring_k.max(1) },
        "grid" => Topology::Grid,
        "er" | "erdos" => Topology::ErdosRenyi { p: cfg.edge_prob },
        "full" => Topology::FullyConnected,
        other => {
            return Err(DdlError::Config(format!(
                "serve: unknown topology '{other}' (ring|grid|er|full)"
            )))
        }
    };
    Ok((Graph::generate(cfg.agents, &topo, rng), topo))
}

/// Synthetic request stream, dispatched on `cfg.stream`: `planted`
/// (default; sparse non-negative combinations of one planted dictionary
/// plus light noise — the service's "patches"), `shift` (piecewise-
/// stationary: the planted dictionary is redrawn at seed-derived
/// boundaries), or `field` (spatially-correlated sensor snapshots,
/// [`crate::data::FieldModel`]). Returns
/// `(arrival_us, x)` pairs in arrival order (all zeros when
/// `cfg.rate == 0`, Poisson gaps otherwise). With `cfg.burst > 1` the
/// requests arrive in clumps of `burst` sharing one timestamp, with
/// exponential inter-clump gaps of mean `burst/rate` so the long-run rate
/// is unchanged — the bursty workload the adaptive batch controller is
/// benchmarked on (`benches/bench_control.rs`). `burst = 1` draws exactly
/// the gap sequence of the plain Poisson stream, bit-for-bit. This is the
/// single definition of the serving workload — `benches/bench_serve.rs`
/// and the examples draw from it too, so BENCH_serve.json always measures
/// the stream the session serves.
pub fn generate_stream(cfg: &ServeConfig, rng: &mut Pcg64) -> Result<Vec<(u64, Vec<f32>)>> {
    match cfg.stream.as_str() {
        "planted" => planted_stream(cfg, rng),
        "shift" => shift_stream(cfg, rng),
        "field" => field_stream(cfg, rng),
        other => Err(DdlError::Config(format!(
            "serve: unknown stream '{other}' (planted|shift|field)"
        ))),
    }
}

/// Advance the Poisson-clump arrival clock for sample `i` — one
/// exponential gap per clump of `burst` requests, mean scaled so the
/// long-run rate is the configured one (`burst = 1` is the plain Poisson
/// stream). Shared by every stream kind so their arrival processes are
/// identical for identical RNG states.
fn arrival_advance(rng: &mut Pcg64, mean_gap_us: f64, burst: usize, i: usize, t_us: &mut f64) {
    if mean_gap_us > 0.0 && i % burst == 0 {
        let u = rng.next_f64().max(1e-12);
        *t_us += -u.ln() * mean_gap_us * burst as f64;
    }
}

/// The default stationary workload: 2-sparse combinations of one planted
/// dictionary (bit-for-bit the pre-`stream` behavior).
fn planted_stream(cfg: &ServeConfig, rng: &mut Pcg64) -> Result<Vec<(u64, Vec<f32>)>> {
    let m = cfg.dim;
    let planted = DistributedDictionary::random(
        m,
        cfg.agents,
        cfg.agents,
        AtomConstraint::UnitBall,
        rng,
    )?;
    let mut out = Vec::with_capacity(cfg.samples);
    let mut t_us = 0f64;
    let mean_gap_us = if cfg.rate > 0.0 { 1e6 / cfg.rate } else { 0.0 };
    let burst = cfg.burst.max(1);
    for i in 0..cfg.samples {
        let mut x = vec![0.0f32; m];
        for _ in 0..2 {
            let q = rng.next_below(cfg.agents as u64) as usize;
            let c = 0.5 + rng.next_f32();
            crate::math::vector::axpy(c, &planted.atom(q), &mut x);
        }
        for v in x.iter_mut() {
            *v += 0.01 * rng.next_normal();
        }
        arrival_advance(rng, mean_gap_us, burst, i, &mut t_us);
        out.push((t_us as u64, x));
    }
    Ok(out)
}

/// Piecewise-stationary workload: `shift_count + 1` stationary segments,
/// each drawing from its own planted dictionary, with segment boundaries
/// jittered around the equal partition by seed-derived offsets — shift
/// times are pure functions of the stream seed, so shift scenarios replay
/// bit-identically. This is the thaw/controller test bed: at each boundary
/// the frozen dictionary's loss jumps, which is exactly the signal the
/// convergence detector thaws on.
fn shift_stream(cfg: &ServeConfig, rng: &mut Pcg64) -> Result<Vec<(u64, Vec<f32>)>> {
    let m = cfg.dim;
    let segments = cfg.shift_count + 1;
    // All segment dictionaries are drawn before the boundary jitter so the
    // sample values of segment 0 do not depend on `shift_count` ordering
    // subtleties — everything is still one deterministic draw sequence.
    let dicts = (0..segments)
        .map(|_| {
            DistributedDictionary::random(m, cfg.agents, cfg.agents, AtomConstraint::UnitBall, rng)
        })
        .collect::<Result<Vec<_>>>()?;
    let mut bounds = Vec::with_capacity(cfg.shift_count);
    for s in 1..segments {
        let base = (s * cfg.samples / segments) as i64;
        let span = (cfg.samples / (4 * segments)).max(1) as i64;
        let jitter = rng.next_below((2 * span + 1) as u64) as i64 - span;
        bounds.push((base + jitter).clamp(1, cfg.samples.saturating_sub(1) as i64) as usize);
    }
    bounds.sort_unstable();
    let mut out = Vec::with_capacity(cfg.samples);
    let mut t_us = 0f64;
    let mean_gap_us = if cfg.rate > 0.0 { 1e6 / cfg.rate } else { 0.0 };
    let burst = cfg.burst.max(1);
    let mut seg = 0usize;
    for i in 0..cfg.samples {
        while seg < bounds.len() && i >= bounds[seg] {
            seg += 1;
        }
        let planted = &dicts[seg];
        let mut x = vec![0.0f32; m];
        for _ in 0..2 {
            let q = rng.next_below(cfg.agents as u64) as usize;
            let c = 0.5 + rng.next_f32();
            crate::math::vector::axpy(c, &planted.atom(q), &mut x);
        }
        for v in x.iter_mut() {
            *v += 0.01 * rng.next_normal();
        }
        arrival_advance(rng, mean_gap_us, burst, i, &mut t_us);
        out.push((t_us as u64, x));
    }
    Ok(out)
}

/// Sensor-network field-monitoring workload (arXiv:1304.3568 setting):
/// each request is one spatially-correlated snapshot of an `M`-sensor
/// field ([`crate::data::FieldModel`]).
fn field_stream(cfg: &ServeConfig, rng: &mut Pcg64) -> Result<Vec<(u64, Vec<f32>)>> {
    let model = crate::data::FieldModel::new(
        cfg.dim,
        cfg.field_sources,
        cfg.field_width,
        cfg.field_noise,
    );
    let mut out = Vec::with_capacity(cfg.samples);
    let mut t_us = 0f64;
    let mean_gap_us = if cfg.rate > 0.0 { 1e6 / cfg.rate } else { 0.0 };
    let burst = cfg.burst.max(1);
    let mut x = vec![0.0f32; cfg.dim];
    for i in 0..cfg.samples {
        model.sample_into(rng, &mut x);
        arrival_advance(rng, mean_gap_us, burst, i, &mut t_us);
        out.push((t_us as u64, x.clone()));
    }
    Ok(out)
}

/// Boundary sample indices at which the `shift` stream's planted
/// dictionary changes, for a given config — recomputed from the seed the
/// same way the stream generator derives them (the coordinator and tests
/// use this to line thaw events up with shifts).
pub fn shift_boundaries(cfg: &ServeConfig) -> Result<Vec<usize>> {
    if cfg.stream != "shift" {
        return Ok(Vec::new());
    }
    // Re-run the setup draw order (topology → dict0 → stream prefix) so
    // the jitter draws land on the same RNG offsets as in `setup`.
    let mut rng = Pcg64::new(cfg.seed);
    build_topology(cfg, &mut rng)?;
    DistributedDictionary::random(
        cfg.dim,
        cfg.agents,
        cfg.agents,
        serve_task(cfg).atom_constraint(),
        &mut rng,
    )?;
    let segments = cfg.shift_count + 1;
    for _ in 0..segments {
        DistributedDictionary::random(
            cfg.dim,
            cfg.agents,
            cfg.agents,
            AtomConstraint::UnitBall,
            &mut rng,
        )?;
    }
    let mut bounds = Vec::with_capacity(cfg.shift_count);
    for s in 1..segments {
        let base = (s * cfg.samples / segments) as i64;
        let span = (cfg.samples / (4 * segments)).max(1) as i64;
        let jitter = rng.next_below((2 * span + 1) as u64) as i64 - span;
        bounds.push((base + jitter).clamp(1, cfg.samples.saturating_sub(1) as i64) as usize);
    }
    bounds.sort_unstable();
    Ok(bounds)
}

/// The serving task: sparse coding with the configured elastic-net knobs.
pub(crate) fn serve_task(cfg: &ServeConfig) -> TaskSpec {
    TaskSpec::SparseCoding { gamma: cfg.infer.gamma, delta: cfg.infer.delta }
}

/// Diffusion parameters for each served batch.
pub(crate) fn serve_params(cfg: &ServeConfig) -> DiffusionParams {
    DiffusionParams::new(cfg.infer.mu, cfg.infer.iters).with_threads(cfg.infer.threads)
}

/// Build a service engine over `graph`: CSR combine for sparse topologies;
/// the dense constructor auto-detects the uniform fast path for "full".
pub(crate) fn build_engine(
    cfg: &ServeConfig,
    graph: &Graph,
    topo: &Topology,
) -> Result<DiffusionEngine> {
    if matches!(topo, Topology::FullyConnected) {
        DiffusionEngine::new(&metropolis_weights(graph), cfg.dim, informed_slice(cfg).as_deref())
    } else {
        DiffusionEngine::new_csr(metropolis_csr(graph), cfg.dim, informed_slice(cfg).as_deref())
    }
}

/// Deterministic session ingredients shared by the serial and pipelined
/// executors. One RNG consumption order (topology → initial dictionary →
/// request stream) means every executor serves the identical workload from
/// the identical starting dictionary for a given config.
pub(crate) struct SessionSetup {
    pub graph: Graph,
    pub topo: Topology,
    pub dict0: DistributedDictionary,
    pub stream: Vec<(u64, Vec<f32>)>,
    /// Norm threshold of the poisoned-sample screen (`None` = screen off).
    /// Computed at setup over the post-poison stream, so it is a pure
    /// function of (config, seed) and both executors screen identically.
    pub screen: Option<f64>,
}

pub(crate) fn setup(cfg: &ServeConfig) -> Result<SessionSetup> {
    let mut rng = Pcg64::new(cfg.seed);
    let (graph, topo) = build_topology(cfg, &mut rng)?;
    let dict0 = DistributedDictionary::random(
        cfg.dim,
        cfg.agents,
        cfg.agents,
        serve_task(cfg).atom_constraint(),
        &mut rng,
    )?;
    let mut stream = generate_stream(cfg, &mut rng)?;
    if cfg.poison {
        poison_stream(cfg, &mut stream);
    }
    let screen = (cfg.poison && cfg.poison_screen).then(|| {
        let norms: Vec<f64> =
            stream.iter().map(|(_, x)| crate::serve::queue::sample_norm(x)).collect();
        crate::serve::queue::poison_norm_threshold(&norms, cfg.poison_screen_z)
    });
    Ok(SessionSetup { graph, topo, dict0, stream, screen })
}

/// Data-poisoning attack on the inbound stream (`--poison`): each sample
/// is corrupted with probability `poison_frac` by large additive Gaussian
/// noise of scale `poison_scale` per coordinate. The poisoner draws from
/// its own dedicated RNG stream (`seed ^ 0x5015_0EED`), *after* stream
/// generation — the arrival process, the honest sample bits, and every
/// other RNG stream of the session are untouched, so a `poison_frac = 0`
/// run is bit-identical to an unpoisoned one and poisoned runs replay
/// bit-identically.
fn poison_stream(cfg: &ServeConfig, stream: &mut [(u64, Vec<f32>)]) {
    let mut rng = Pcg64::new(cfg.seed ^ 0x5015_0EED);
    for (_, x) in stream.iter_mut() {
        if rng.next_f64() < cfg.poison_frac {
            for v in x.iter_mut() {
                *v += cfg.poison_scale * rng.next_normal();
            }
        }
    }
}

/// Loss of the first and last quarter of batches (the gap shows online
/// adaptation while serving).
pub(crate) fn loss_quarters(batch_losses: &[f64]) -> (f64, f64) {
    let quarter = (batch_losses.len() / 4).max(1);
    let first: Vec<f64> = batch_losses.iter().take(quarter).cloned().collect();
    let last: Vec<f64> = batch_losses.iter().rev().take(quarter).cloned().collect();
    (stats::mean(&first), stats::mean(&last))
}

/// Run a streaming session; `log` receives progress lines. Dispatches to
/// the pipelined executor when `cfg.pipeline` is set, else runs the serial
/// single-server loop.
pub fn run_service(cfg: &ServeConfig, log: &mut dyn FnMut(&str)) -> Result<ServeReport> {
    run_service_with_dict(cfg, log).map(|(report, _)| report)
}

/// [`run_service`] variant that also returns the final adapted dictionary
/// (the parity tests compare it bitwise across executors).
pub fn run_service_with_dict(
    cfg: &ServeConfig,
    log: &mut dyn FnMut(&str),
) -> Result<(ServeReport, DistributedDictionary)> {
    if cfg.pipeline {
        crate::serve::pipeline::run_pipelined(cfg, crate::serve::PipelineExec::Threaded, log)
    } else {
        run_serial(cfg, log)
    }
}

/// The serial single-server discrete-event loop (PR 2 semantics): batch
/// formation couples to measured service times, and each batch's update
/// completes before the next batch's inference starts (no staleness).
///
/// With `[control] enabled` (the `--adaptive` mode) the loop runs on the
/// deterministic [`ServiceModel`] clock instead of measured wall time, and
/// a [`BatchController`] re-decides the queue policy each control tick —
/// every decision a pure function of (config, seed, stream), so adaptive
/// runs replay bit-identically (`tests/control_adaptive.rs`). The batches
/// are still *processed for real* (the dictionary adapts with genuine
/// arithmetic); only the clock is modeled.
fn run_serial(
    cfg: &ServeConfig,
    log: &mut dyn FnMut(&str),
) -> Result<(ServeReport, DistributedDictionary)> {
    let m = cfg.dim;
    let SessionSetup { graph, topo, dict0: mut dict, stream, screen } = setup(cfg)?;
    let directed_edges = 2 * graph.edge_count();

    let mut engine = build_engine(cfg, &graph, &topo)?;
    let combine_path = engine.combine_path();
    if cfg.infer.threads > 1 {
        // Long-lived workers: the serving loop enters one SPMD region per
        // batch, so per-batch thread spawns are pure overhead.
        engine.set_pool(std::sync::Arc::new(crate::net::PersistentPool::new(
            cfg.infer.threads,
        )));
    }

    let task = serve_task(cfg);
    let params = serve_params(cfg);
    let mut trainer =
        OnlineTrainer::from_engine(engine, TrainerOptions { infer: params, prox: DictProx::None });

    let adaptive = cfg.control.enabled;
    // Convergence detector: with `[convergence] tol = 0` (the default) it
    // observes nothing and this loop is bit-for-bit the always-adapt run.
    let mut detector = ConvergenceDetector::new(cfg.convergence.clone());
    let model = ServiceModel::from_config(&cfg.control);
    // Optional service-model calibration: measure the first K batches on
    // the wall clock, least-squares fit the affine law, freeze it for the
    // rest of the session (`[control] calibrate`, default off).
    let mut calibrator =
        (adaptive && cfg.control.calibrate).then(|| ServiceCalibrator::from_config(&cfg.control));
    let mut controller =
        if adaptive { Some(BatchController::new(&cfg.control, cfg.batch, cfg.max_wait_us)) } else { None };
    let init_policy = match &controller {
        Some(c) => c.policy(),
        None => BatchPolicy::new(cfg.batch, cfg.max_wait_us),
    };
    let mut queue = MicroBatchQueue::with_capacity(init_policy, cfg.queue_capacity);
    log(&format!(
        "serve{}: N={} M={} topology={} ({} directed edges, {} combine), B<={}, max_wait={}µs, \
         {} samples at {}",
        if adaptive { "[adaptive]" } else { "" },
        cfg.agents,
        m,
        cfg.topology,
        directed_edges,
        combine_path,
        init_policy.max_batch,
        init_policy.max_wait_us,
        cfg.samples,
        if cfg.rate > 0.0 { format!("{:.0} req/s", cfg.rate) } else { "saturation".into() },
    ));

    // Trace sink: events are stamped with the loop's virtual clock
    // (`now_us`), which tracing never advances (`tests/obs_parity.rs`).
    let obs = crate::obs::handle_for(&cfg.obs);
    let mut stats = MessageStats::default();
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(cfg.samples);
    let mut batch_losses: Vec<f64> = Vec::new();
    let mut now_us: u64 = 0;
    let mut served = 0usize;
    let mut quarantined = 0usize;
    let mut next = 0usize;

    while next < stream.len() || !queue.is_empty() {
        // Admit every request that has arrived by the current clock. A
        // bounded queue sheds the overflow (typed rejection, counted,
        // traced) instead of queueing without limit.
        while next < stream.len() && stream[next].0 <= now_us {
            let (t, x) = (stream[next].0, stream[next].1.clone());
            match queue.try_push(x, t) {
                Ok(_) => {}
                Err(DdlError::QueueFull { capacity }) => {
                    if obs.enabled() {
                        obs.instant(
                            now_us,
                            "queue_shed",
                            crate::obs::Track::Stage("form"),
                            vec![
                                ("capacity", crate::obs::ArgValue::U(capacity as u64)),
                                ("arrival_us", crate::obs::ArgValue::U(t)),
                            ],
                        );
                    }
                    if let Some(ctl) = controller.as_mut() {
                        ctl.observe_shed(1);
                    }
                }
                Err(e) => return Err(e),
            }
            next += 1;
        }
        let end_of_stream = next >= stream.len();
        let batch = if queue.ready(now_us) {
            queue.drain_batch()
        } else if end_of_stream && !queue.is_empty() {
            // Final partial batch: nothing else will arrive.
            queue.drain_batch()
        } else {
            // Idle: jump the clock to the next arrival or batch deadline.
            let mut t_next = u64::MAX;
            if next < stream.len() {
                t_next = t_next.min(stream[next].0);
            }
            if let Some(d) = queue.next_deadline_us() {
                t_next = t_next.min(d);
            }
            if t_next == u64::MAX {
                break;
            }
            now_us = now_us.max(t_next);
            continue;
        };

        // Poisoned-sample screen: quarantine norm outliers before they
        // reach the engine or the Eq. 51 update. The min-norm sample is
        // always kept, so the batch never screens down to empty.
        // Quarantined samples are not served — they pay no latency entry
        // and ride the controller's shed/overload path.
        let batch = match screen {
            Some(threshold) => {
                let (kept, dropped) = crate::serve::queue::screen_batch(batch, threshold);
                if !dropped.is_empty() {
                    quarantined += dropped.len();
                    if obs.enabled() {
                        obs.instant(
                            now_us,
                            "sample_quarantined",
                            crate::obs::Track::Stage("form"),
                            vec![(
                                "count",
                                crate::obs::ArgValue::U(dropped.len() as u64),
                            )],
                        );
                    }
                    if let Some(ctl) = controller.as_mut() {
                        ctl.observe_shed(dropped.len());
                    }
                }
                kept
            }
            None => batch,
        };

        if obs.enabled() {
            obs.instant(
                now_us,
                "batch_form",
                crate::obs::Track::Stage("form"),
                vec![(
                    "size",
                    crate::obs::ArgValue::U(batch.len() as u64),
                )],
            );
            obs.counter(now_us, "queue_depth", crate::obs::Track::Stage("form"), queue.len() as f64);
        }
        let formed_us = now_us;

        // Process the minibatch for real: batched inference + one online
        // dictionary update (each sample seen exactly once). Adaptive
        // sessions advance the clock by the deterministic service model
        // instead of the measured wall time (the replay anchor).
        let refs: Vec<&[f32]> = batch.iter().map(|r| r.x.as_slice()).collect();
        // A frozen batch runs pure inference (the Eq. 51 update is
        // skipped); the decision was made at the previous batch boundary,
        // so it is deterministic regardless of wall timing.
        let frozen = detector.is_frozen();
        let t0 = Instant::now();
        let step = if frozen {
            trainer.step_frozen(&dict, &task, &refs)?
        } else {
            trainer.step(&mut dict, &task, &refs, cfg.mu_w)?
        };
        let wall_us = (t0.elapsed().as_secs_f64() * 1e6).ceil().max(1.0) as u64;
        let service_us = if adaptive {
            let mdl = if let Some(cal) = calibrator.as_mut() {
                // Pre-freeze the configured model drives the clock while
                // the calibrator records wall measurements on the side;
                // from the freeze on the fitted model takes over.
                if cal.observe(batch.len(), wall_us) {
                    let fitted = cal.model();
                    log(&format!(
                        "  calibrated service model from {} batches: {} + {}µs/sample",
                        cfg.control.calib_batches, fitted.base_us, fitted.per_sample_us
                    ));
                }
                cal.model()
            } else {
                model
            };
            let full = mdl.service_us(batch.len());
            if frozen {
                // The serial loop pays inference + update in one charge;
                // a frozen batch skips the update share — the serial form
                // of "the update slot is released to pure inference".
                full.saturating_sub(mdl.update_us(batch.len()))
            } else {
                full
            }
        } else {
            wall_us
        };
        now_us = now_us.saturating_add(service_us);
        if obs.enabled() {
            // One span covering inference + update (the serial loop has
            // no stage overlap): formed → clock after the service charge.
            obs.span_begin(formed_us, "service", crate::obs::Track::Stage("infer"));
            obs.span_end(now_us, "service", crate::obs::Track::Stage("infer"));
        }

        batch_losses.push(step.mean_loss);
        let was_frozen = detector.is_frozen();
        let events = detector.observe(batch_losses.len() - 1, &dict, step.mean_loss);
        emit_conv_events(&obs, now_us, events);
        if detector.is_frozen() != was_frozen {
            log(&format!(
                "  convergence: {} adaptation at batch {}",
                if detector.is_frozen() { "froze" } else { "thawed" },
                batch_losses.len() - 1,
            ));
        }
        served += batch.len();
        for r in &batch {
            latencies_ms.push(now_us.saturating_sub(r.arrival_us) as f64 / 1e3);
        }
        if let Some(ctl) = controller.as_mut() {
            let from = latencies_ms.len() - batch.len();
            // The serial loop applies decisions synchronously, so the
            // queue's current cap is the cap this batch was formed under.
            ctl.observe_batch(batch.len(), queue.policy().max_batch, &latencies_ms[from..]);
            if let Some(policy) = ctl.maybe_decide(now_us) {
                if obs.enabled() {
                    obs.instant(
                        now_us,
                        "batch_policy",
                        crate::obs::Track::Controller("batch"),
                        vec![
                            ("max_batch", crate::obs::ArgValue::U(policy.max_batch as u64)),
                            ("max_wait_us", crate::obs::ArgValue::U(policy.max_wait_us)),
                        ],
                    );
                }
                queue.set_policy(policy);
            }
        }
        // ψ traffic for this batch: one message per directed edge per
        // diffusion iteration carrying the whole minibatch (B·M floats) —
        // payload bytes match B sequential BSP runs exactly, while the
        // per-message headers are amortized B× (a real serving win; see
        // EXPERIMENTS.md §Serving).
        stats.record_exchange(directed_edges * cfg.infer.iters, batch.len() * m);
        stats.add_rounds(cfg.infer.iters);

        if batch_losses.len() % 16 == 0 {
            log(&format!(
                "  [{:>6.2} s] served {:>5}/{} (loss {:.4})",
                now_us as f64 / 1e6,
                served,
                cfg.samples,
                step.mean_loss
            ));
        }
    }

    let batches = batch_losses.len();
    let duration_s = (now_us as f64 / 1e6).max(1e-9);
    let (loss_first_quarter, loss_last_quarter) = loss_quarters(&batch_losses);
    // Sort the latency vector once for every percentile the report needs.
    let pct = stats::Percentiles::new(&latencies_ms);
    let report = ServeReport {
        mode: if adaptive { "serial-adaptive" } else { "serial" },
        pipeline_depth: 0,
        samples: served,
        batches,
        shed: queue.shed_count() as usize,
        quarantined,
        mean_batch: if batches > 0 { served as f64 / batches as f64 } else { 0.0 },
        duration_s,
        throughput_rps: served as f64 / duration_s,
        latency_p50_ms: pct.get(50.0),
        latency_p95_ms: pct.get(95.0),
        latency_p99_ms: pct.get(99.0),
        latency_max_ms: pct.max(),
        loss_first_quarter,
        loss_last_quarter,
        stats,
        combine_path,
        adaptive,
        slo_p99_ms: cfg.control.slo_p99_ms,
        slo_violation_frac: slo_violation_frac(&latencies_ms, cfg.control.slo_p99_ms),
        decisions: controller.map(|c| c.into_decisions()).unwrap_or_default(),
        depth_trace: Vec::new(),
        frozen_batches: detector.frozen_batches(),
        conv_events: detector.into_events(),
    };
    if let Some(n) = crate::obs::export(&cfg.obs, &obs)? {
        log(&format!(
            "trace: wrote {n} events to {}",
            cfg.obs.trace_path.as_deref().unwrap_or("?")
        ));
    }
    Ok((report, dict))
}

/// Mirror convergence-detector events as obs instants on the `conv`
/// controller lane, stamped at the executor's current virtual clock.
/// Shared by the serial loop and the pipelined updater stage so the trace
/// vocabulary is identical across executors.
pub(crate) fn emit_conv_events(
    obs: &crate::obs::ObsHandle,
    t_us: u64,
    events: &[ConvEvent],
) {
    if !obs.enabled() || events.is_empty() {
        return;
    }
    let lane = || crate::obs::Track::Controller("conv");
    for ev in events {
        match *ev {
            ConvEvent::Drift { batch, norm } => obs.instant(
                t_us,
                "drift_norm",
                lane(),
                vec![
                    ("batch", crate::obs::ArgValue::U(batch as u64)),
                    ("norm", crate::obs::ArgValue::F(norm)),
                    ("frozen", crate::obs::ArgValue::B(false)),
                ],
            ),
            ConvEvent::LossRatio { batch, ratio } => obs.instant(
                t_us,
                "drift_norm",
                lane(),
                vec![
                    ("batch", crate::obs::ArgValue::U(batch as u64)),
                    ("norm", crate::obs::ArgValue::F(ratio)),
                    ("frozen", crate::obs::ArgValue::B(true)),
                ],
            ),
            ConvEvent::Freeze { batch } => obs.instant(
                t_us,
                "freeze",
                lane(),
                vec![("batch", crate::obs::ArgValue::U(batch as u64))],
            ),
            ConvEvent::Thaw { batch } => obs.instant(
                t_us,
                "thaw",
                lane(),
                vec![("batch", crate::obs::ArgValue::U(batch as u64))],
            ),
        }
    }
}

/// Fraction of request latencies exceeding the SLO (0.0 on an empty run).
pub(crate) fn slo_violation_frac(latencies_ms: &[f64], slo_ms: f64) -> f64 {
    if latencies_ms.is_empty() {
        return 0.0;
    }
    latencies_ms.iter().filter(|&&l| l > slo_ms).count() as f64 / latencies_ms.len() as f64
}

fn informed_slice(cfg: &ServeConfig) -> Option<Vec<usize>> {
    // `Some(0)` maps to an empty set so the engine's "at least one informed
    // agent" validation fires instead of silently serving with one agent.
    cfg.informed.map(|k| (0..k).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ServeConfig {
        let base = ServeConfig::default();
        ServeConfig {
            agents: 12,
            dim: 8,
            topology: "ring".into(),
            ring_k: 1,
            batch: 4,
            max_wait_us: 200,
            samples: 24,
            rate: 0.0,
            infer: crate::config::experiment::InferenceConfig {
                iters: 15,
                threads: 1,
                ..base.infer.clone()
            },
            ..base
        }
    }

    #[test]
    fn saturated_session_serves_every_sample() {
        let cfg = tiny_cfg();
        let mut lines = Vec::new();
        let report = run_service(&cfg, &mut |s| lines.push(s.to_string())).unwrap();
        assert_eq!(report.samples, 24);
        // Saturated arrivals form full batches: 24 / 4.
        assert_eq!(report.batches, 6);
        assert!((report.mean_batch - 4.0).abs() < 1e-9);
        assert!(report.throughput_rps > 0.0);
        assert!(report.latency_p50_ms <= report.latency_p99_ms + 1e-9);
        // One round per diffusion iteration per batch.
        assert_eq!(report.stats.rounds, 6 * cfg.infer.iters);
        assert!(report.stats.messages > 0);
        assert!(report.stats.bytes_per_agent_round(cfg.agents) > 0.0);
    }

    #[test]
    fn paced_session_forms_partial_batches() {
        let mut cfg = tiny_cfg();
        // Arrivals far slower than the wait budget: batches close by
        // deadline well below the size cap (gaps are exponential, so a
        // rare cluster may still pair two samples — bound, don't pin).
        cfg.rate = 5.0; // ~200 ms mean gap vs 200 µs max wait
        cfg.samples = 6;
        let report = run_service(&cfg, &mut |_| {}).unwrap();
        assert_eq!(report.samples, 6);
        assert!(
            report.batches >= 3 && report.batches <= 6,
            "expected mostly-singleton batches, got {}",
            report.batches
        );
        assert!(report.mean_batch < cfg.batch as f64);
        // Deadline releases dominate latency: every request waited at
        // least the max-wait budget but far less than one arrival gap.
        assert!(report.latency_p50_ms >= cfg.max_wait_us as f64 / 1e3 * 0.5);
    }

    #[test]
    fn adaptation_reduces_loss_on_stream() {
        let mut cfg = tiny_cfg();
        cfg.samples = 192;
        cfg.infer.iters = 100;
        cfg.infer.mu = 0.3;
        cfg.mu_w = 0.08;
        let report = run_service(&cfg, &mut |_| {}).unwrap();
        assert!(
            report.loss_last_quarter < report.loss_first_quarter,
            "online adaptation should reduce loss: {} -> {}",
            report.loss_first_quarter,
            report.loss_last_quarter
        );
    }

    /// The adaptive serial loop serves every sample on the virtual model
    /// clock, reports its mode, and records controller decisions.
    #[test]
    fn adaptive_serial_session_runs_on_model_clock() {
        let mut cfg = tiny_cfg();
        cfg.control.enabled = true;
        let report = run_service(&cfg, &mut |_| {}).unwrap();
        assert_eq!(report.mode, "serial-adaptive");
        assert!(report.adaptive);
        assert_eq!(report.samples, 24);
        assert!(!report.decisions.is_empty(), "ticks must have fired");
        // The clock is the virtual model, not wall time: 24 samples at
        // 150 µs/sample plus at most 6 batch overheads of 800 µs — the
        // duration is bounded by the model arithmetic and bit-stable
        // across runs regardless of machine speed.
        assert!(report.duration_s >= 24.0 * 150e-6, "got {}", report.duration_s);
        assert!(report.duration_s <= 24.0 * 150e-6 + 6.0 * 800e-6, "got {}", report.duration_s);
        let replay = run_service(&cfg, &mut |_| {}).unwrap();
        assert_eq!(report.duration_s.to_bits(), replay.duration_s.to_bits());
        assert_eq!(report.decisions, replay.decisions);
        assert_eq!(report.slo_p99_ms, cfg.control.slo_p99_ms);
        assert!(report.slo_violation_frac >= 0.0 && report.slo_violation_frac <= 1.0);
    }

    /// A bounded admission queue sheds the saturated-arrival overflow
    /// deterministically: all 24 samples land at t = 0, capacity 10
    /// admits exactly 10 and sheds the rest, and replay is bit-stable.
    #[test]
    fn bounded_queue_sheds_overflow_storm() {
        let mut cfg = tiny_cfg();
        cfg.queue_capacity = 10;
        let report = run_service(&cfg, &mut |_| {}).unwrap();
        assert_eq!(report.shed, 14);
        assert_eq!(report.samples, 10);
        let replay = run_service(&cfg, &mut |_| {}).unwrap();
        assert_eq!(replay.shed, 14);
        assert_eq!(replay.samples, 10);
        // The default unbounded queue never sheds.
        assert_eq!(run_service(&tiny_cfg(), &mut |_| {}).unwrap().shed, 0);
    }

    /// Stream dispatch: shift/field streams replay per seed, differ from
    /// the planted stream, and unknown kinds are rejected with a typed
    /// config error. Shift boundaries recompute identically from the seed.
    #[test]
    fn stream_kinds_dispatch_and_replay() {
        let mut cfg = tiny_cfg();
        cfg.stream = "shift".into();
        let a = setup(&cfg).unwrap().stream;
        let b = setup(&cfg).unwrap().stream;
        assert_eq!(a, b, "shift stream must replay bit-identically");
        let bounds = shift_boundaries(&cfg).unwrap();
        assert_eq!(bounds.len(), cfg.shift_count);
        assert!(bounds.windows(2).all(|w| w[0] <= w[1]));
        assert!(bounds.iter().all(|&x| x >= 1 && x < cfg.samples));
        assert_eq!(bounds, shift_boundaries(&cfg).unwrap());
        cfg.stream = "field".into();
        let f = setup(&cfg).unwrap().stream;
        assert_eq!(f.len(), cfg.samples);
        assert_ne!(f[0].1, a[0].1, "field snapshots differ from planted patches");
        assert!(shift_boundaries(&cfg).unwrap().is_empty(), "only shift streams shift");
        cfg.stream = "fourier".into();
        assert!(run_service(&cfg, &mut |_| {}).is_err());
    }

    /// An aggressive convergence config freezes the adaptive session, the
    /// frozen batches stop paying the update charge (strictly shorter
    /// virtual duration than the always-adapt run), and a stationary
    /// stream never thaws.
    #[test]
    fn convergence_freeze_speeds_up_adaptive_session() {
        let mut cfg = tiny_cfg();
        cfg.samples = 96;
        cfg.control.enabled = true;
        cfg.convergence.tol = 10.0; // any measured drift counts as converged
        cfg.convergence.window = 2;
        cfg.convergence.max_no_improvement = 1;
        let frozen = run_service(&cfg, &mut |_| {}).unwrap();
        assert!(frozen.frozen_batches > 0, "session never froze");
        assert!(frozen
            .conv_events
            .iter()
            .any(|e| matches!(e, crate::learn::ConvEvent::Freeze { .. })));
        assert!(
            frozen.conv_events.iter().all(|e| !matches!(e, crate::learn::ConvEvent::Thaw { .. })),
            "stationary stream must not thaw"
        );
        // Replay contract: decisions and clock are bit-stable.
        let replay = run_service(&cfg, &mut |_| {}).unwrap();
        assert_eq!(frozen.conv_events, replay.conv_events);
        assert_eq!(frozen.duration_s.to_bits(), replay.duration_s.to_bits());
        // The tol = 0 baseline adapts every batch and pays for it.
        let mut base = cfg.clone();
        base.convergence.tol = 0.0;
        let adapt = run_service(&base, &mut |_| {}).unwrap();
        assert_eq!(adapt.frozen_batches, 0);
        assert!(adapt.conv_events.is_empty());
        assert!(
            frozen.duration_s < adapt.duration_s,
            "frozen batches must shed the update charge: {} vs {}",
            frozen.duration_s,
            adapt.duration_s
        );
        assert!(frozen.throughput_rps > adapt.throughput_rps);
    }

    /// The poisoning attack and its screen: a poisoned session
    /// quarantines the corrupted samples before they reach Eq. 51 and the
    /// defended loss stays far below the undefended run; `poison_frac = 0`
    /// with the screen armed quarantines nothing and is bit-identical to
    /// the unpoisoned session (zero false positives); poisoned runs
    /// replay bit-identically.
    #[test]
    fn poison_screen_quarantines_and_recovers() {
        let mut cfg = tiny_cfg();
        cfg.samples = 96;
        cfg.infer.iters = 40;
        cfg.mu_w = 0.08;
        let clean = run_service(&cfg, &mut |_| {}).unwrap();
        assert_eq!(clean.quarantined, 0);

        let mut p = cfg.clone();
        p.poison = true;
        p.poison_frac = 0.3;
        let defended = run_service(&p, &mut |_| {}).unwrap();
        assert!(defended.quarantined >= 10, "got {}", defended.quarantined);
        assert_eq!(defended.samples + defended.quarantined, 96);
        assert!(defended.summary(p.agents).contains("quarantined"));

        let mut u = p.clone();
        u.poison_screen = false;
        let undefended = run_service(&u, &mut |_| {}).unwrap();
        assert_eq!(undefended.quarantined, 0);
        assert_eq!(undefended.samples, 96);
        assert!(
            undefended.loss_last_quarter > 4.0 * defended.loss_last_quarter,
            "screen must shield the update: undefended {} vs defended {}",
            undefended.loss_last_quarter,
            defended.loss_last_quarter
        );

        // Zero false positives: the armed screen over a clean stream
        // (poison on, frac 0 — no sample is touched) quarantines nothing
        // and the session is bit-identical to the unpoisoned run.
        let mut z = cfg.clone();
        z.poison = true;
        z.poison_frac = 0.0;
        let zfp = run_service(&z, &mut |_| {}).unwrap();
        assert_eq!(zfp.quarantined, 0, "clean stream must never be quarantined");
        assert_eq!(zfp.samples, clean.samples);
        assert_eq!(zfp.batches, clean.batches);
        assert_eq!(zfp.loss_last_quarter.to_bits(), clean.loss_last_quarter.to_bits());

        // Replay contract: the poisoned, defended run is bit-stable.
        let replay = run_service(&p, &mut |_| {}).unwrap();
        assert_eq!(replay.quarantined, defended.quarantined);
        assert_eq!(replay.loss_last_quarter.to_bits(), defended.loss_last_quarter.to_bits());
    }

    #[test]
    fn slo_violation_frac_counts_exceedances() {
        assert_eq!(slo_violation_frac(&[], 10.0), 0.0);
        assert_eq!(slo_violation_frac(&[1.0, 11.0, 9.0, 30.0], 10.0), 0.5);
    }

    #[test]
    fn unknown_topology_rejected() {
        let mut cfg = tiny_cfg();
        cfg.topology = "torus".into();
        assert!(run_service(&cfg, &mut |_| {}).is_err());
    }

    #[test]
    fn zero_informed_agents_rejected() {
        let mut cfg = tiny_cfg();
        cfg.informed = Some(0);
        assert!(run_service(&cfg, &mut |_| {}).is_err());
    }
}
