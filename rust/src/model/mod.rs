//! Model types: the distributed dictionary and the task family
//! (residual loss + regularizer pairs from paper Tables I–II).

pub mod dictionary;
pub mod task;

pub use dictionary::{DictDoubleBuffer, DistributedDictionary};
pub use task::{AtomConstraint, TaskSpec};
