//! The model-distributed dictionary `W = [W_1 … W_N]` (Eq. 8).
//!
//! Each agent `k` owns a contiguous block of atom columns `W_k`
//! (`M × N_k`); the paper's experiments use one atom per agent
//! (`N_k = 1`), but the type supports arbitrary blocks so the library
//! scales to fewer agents than atoms.

use crate::error::{DdlError, Result};
use crate::math::Mat;
use crate::model::AtomConstraint;
use crate::ops::project::{project_columns_nonneg_unit_ball, project_columns_unit_ball};
use crate::rng::Pcg64;

/// Distributed dictionary: an `M × K` matrix with an agent→atom-block map.
#[derive(Clone, Debug)]
pub struct DistributedDictionary {
    /// Row-major `M × K` atom matrix.
    w: Mat,
    /// `blocks[k] = (start, len)`: agent `k` owns atoms
    /// `start..start+len`.
    blocks: Vec<(usize, usize)>,
}

impl DistributedDictionary {
    /// Random initialization (paper §IV-B: iid standard normal entries,
    /// then columns scaled into the constraint set).
    pub fn random(
        m: usize,
        k: usize,
        agents: usize,
        constraint: AtomConstraint,
        rng: &mut Pcg64,
    ) -> Result<Self> {
        if agents == 0 || k < agents {
            return Err(DdlError::Config(format!(
                "dictionary: need at least one atom per agent (K={k}, N={agents})"
            )));
        }
        let mut w = Mat::from_fn(m, k, |_, _| rng.next_normal());
        if constraint == AtomConstraint::NonNegUnitBall {
            // Non-negative tasks start from |N(0,1)| atoms.
            for v in w.as_mut_slice() {
                *v = v.abs();
            }
        }
        normalize_columns(&mut w);
        let blocks = even_blocks(k, agents);
        Ok(DistributedDictionary { w, blocks })
    }

    /// Wrap an existing matrix with an even agent partition.
    pub fn from_mat(w: Mat, agents: usize) -> Result<Self> {
        let k = w.cols();
        if agents == 0 || k < agents {
            return Err(DdlError::Config(format!(
                "dictionary: need at least one atom per agent (K={k}, N={agents})"
            )));
        }
        let blocks = even_blocks(k, agents);
        Ok(DistributedDictionary { w, blocks })
    }

    /// Data dimension `M`.
    pub fn m(&self) -> usize {
        self.w.rows()
    }

    /// Total atom count `K`.
    pub fn k(&self) -> usize {
        self.w.cols()
    }

    /// Number of agents `N`.
    pub fn agents(&self) -> usize {
        self.blocks.len()
    }

    /// Atom block `(start, len)` of agent `k`.
    pub fn block(&self, k: usize) -> (usize, usize) {
        self.blocks[k]
    }

    /// The full matrix (test/baseline access; a real deployment would never
    /// materialize this at one agent — the point of the paper).
    pub fn mat(&self) -> &Mat {
        &self.w
    }

    /// Mutable access to the full matrix.
    pub fn mat_mut(&mut self) -> &mut Mat {
        &mut self.w
    }

    /// Copy atom `q` into a fresh vector.
    pub fn atom(&self, q: usize) -> Vec<f32> {
        self.w.col(q)
    }

    /// Correlations `s_q = w_qᵀ ν` for every atom `q` in agent `k`'s block,
    /// written into `out[start..start+len]`.
    pub fn block_correlations(&self, k: usize, nu: &[f32], out: &mut [f32]) {
        let (start, len) = self.blocks[k];
        debug_assert_eq!(nu.len(), self.m());
        debug_assert_eq!(out.len(), self.k());
        let kk = self.k();
        let w = self.w.as_slice();
        for q in start..start + len {
            let mut s = 0.0f32;
            for r in 0..self.m() {
                s += w[r * kk + q] * nu[r];
            }
            out[q] = s;
        }
    }

    /// Batched [`Self::block_correlations`]: `nus` holds `batch` contiguous
    /// length-`M` dual iterates (one engine `V` row), and
    /// `out[q*batch + s]` receives `w_qᵀ ν_s` for every atom `q` in agent
    /// `k`'s block. The strided column walk over `W` is done once per atom
    /// and amortized across the minibatch — the inner sum over `r` runs in
    /// the same ascending order as the scalar path, so each sample's result
    /// is bit-identical to a separate [`Self::block_correlations`] call.
    pub fn block_correlations_batched(
        &self,
        k: usize,
        nus: &[f32],
        batch: usize,
        out: &mut [f32],
    ) {
        let (start, len) = self.blocks[k];
        let m = self.m();
        let kk = self.k();
        debug_assert_eq!(nus.len(), batch * m);
        debug_assert_eq!(out.len(), batch * kk);
        let w = self.w.as_slice();
        for q in start..start + len {
            let o = &mut out[q * batch..(q + 1) * batch];
            o.fill(0.0);
            for r in 0..m {
                let wv = w[r * kk + q];
                for (s, ov) in o.iter_mut().enumerate() {
                    *ov += wv * nus[s * m + r];
                }
            }
        }
    }

    /// Add `coeff[q] * w_q` for agent `k`'s atoms into `acc` (length M).
    pub fn block_accumulate(&self, k: usize, coeff: &[f32], acc: &mut [f32]) {
        let (start, len) = self.blocks[k];
        let kk = self.k();
        let w = self.w.as_slice();
        for q in start..start + len {
            let c = coeff[q];
            if c == 0.0 {
                continue;
            }
            for (r, a) in acc.iter_mut().enumerate() {
                *a += c * w[r * kk + q];
            }
        }
    }

    /// Batched [`Self::block_accumulate`]: `coeff[q*batch + s]` scales atom
    /// `q` into the `s`-th length-`M` segment of `acc`. Zero coefficients
    /// are skipped exactly as in the scalar path (thresholded coefficients
    /// are mostly zero), and each sample's accumulation runs atoms in the
    /// same ascending order — per-sample results are bit-identical.
    pub fn block_accumulate_batched(
        &self,
        k: usize,
        coeff: &[f32],
        batch: usize,
        acc: &mut [f32],
    ) {
        let (start, len) = self.blocks[k];
        let m = self.m();
        let kk = self.k();
        debug_assert_eq!(coeff.len(), batch * kk);
        debug_assert_eq!(acc.len(), batch * m);
        let w = self.w.as_slice();
        for q in start..start + len {
            for s in 0..batch {
                let c = coeff[q * batch + s];
                if c == 0.0 {
                    continue;
                }
                let seg = &mut acc[s * m..(s + 1) * m];
                for (r, a) in seg.iter_mut().enumerate() {
                    *a += c * w[r * kk + q];
                }
            }
        }
    }

    /// Rank-1-per-atom dictionary update for agent `k` (Eq. 51, before
    /// prox/projection): `W_k += μ_w · ν yₖᵀ`.
    pub fn block_gradient_step(&mut self, k: usize, mu_w: f32, nu: &[f32], y: &[f32]) {
        let (start, len) = self.blocks[k];
        let kk = self.k();
        let m = self.m();
        let w = self.w.as_mut_slice();
        for q in start..start + len {
            let g = mu_w * y[q];
            if g == 0.0 {
                continue;
            }
            for r in 0..m {
                w[r * kk + q] += g * nu[r];
            }
        }
    }

    /// Project agent `k`'s atoms onto the constraint set.
    pub fn project_block(&mut self, k: usize, constraint: AtomConstraint) {
        let (start, len) = self.blocks[k];
        let kk = self.k();
        let m = self.m();
        let w = self.w.as_mut_slice();
        for q in start..start + len {
            match constraint {
                AtomConstraint::UnitBall => {
                    let mut nsq = 0.0f32;
                    for r in 0..m {
                        nsq += w[r * kk + q] * w[r * kk + q];
                    }
                    if nsq > 1.0 {
                        let inv = 1.0 / nsq.sqrt();
                        for r in 0..m {
                            w[r * kk + q] *= inv;
                        }
                    }
                }
                AtomConstraint::NonNegUnitBall => {
                    let mut nsq = 0.0f32;
                    for r in 0..m {
                        let v = w[r * kk + q].max(0.0);
                        w[r * kk + q] = v;
                        nsq += v * v;
                    }
                    if nsq > 1.0 {
                        let inv = 1.0 / nsq.sqrt();
                        for r in 0..m {
                            w[r * kk + q] *= inv;
                        }
                    }
                }
            }
        }
    }

    /// Overwrite this dictionary's atoms with `src`'s. Both dictionaries
    /// must have the same shape and agent partition — this is the snapshot
    /// primitive of the serving pipeline's double-buffered dictionary
    /// (refresh a read snapshot / recycled buffer from the write side
    /// without allocating).
    pub fn copy_from(&mut self, src: &Self) -> Result<()> {
        if self.m() != src.m() || self.k() != src.k() || self.blocks != src.blocks {
            return Err(DdlError::Shape(format!(
                "dictionary copy_from: shape mismatch ({}×{}/{} agents vs {}×{}/{} agents)",
                self.m(),
                self.k(),
                self.agents(),
                src.m(),
                src.k(),
                src.agents()
            )));
        }
        self.w.as_mut_slice().copy_from_slice(src.w.as_slice());
        Ok(())
    }

    /// Expand the dictionary by `extra` atoms distributed over `new_agents`
    /// additional agents (novelty time-steps, §IV-C: "the dictionary is
    /// expanded by adding nodes to the network"). Existing atoms are
    /// preserved.
    pub fn expand(
        &mut self,
        extra: usize,
        new_agents: usize,
        constraint: AtomConstraint,
        rng: &mut Pcg64,
    ) -> Result<()> {
        if new_agents == 0 || extra < new_agents {
            return Err(DdlError::Config(format!(
                "expand: need at least one atom per new agent (extra={extra}, new={new_agents})"
            )));
        }
        let m = self.m();
        let old_k = self.k();
        let new_k = old_k + extra;
        let mut w = Mat::zeros(m, new_k);
        for r in 0..m {
            let dst = &mut w.as_mut_slice()[r * new_k..r * new_k + old_k];
            dst.copy_from_slice(&self.w.row(r)[..old_k]);
        }
        for q in old_k..new_k {
            let mut col = vec![0.0f32; m];
            for v in col.iter_mut() {
                let g = rng.next_normal();
                *v = if constraint == AtomConstraint::NonNegUnitBall { g.abs() } else { g };
            }
            // Normalize only the new atoms; existing atoms are preserved
            // bit-for-bit ("the previous atoms are preserved", §IV-C1).
            crate::math::vector::normalize(&mut col);
            w.set_col(q, &col);
        }
        self.w = w;
        let added = even_blocks(extra, new_agents)
            .into_iter()
            .map(|(s, l)| (s + old_k, l));
        self.blocks.extend(added);
        Ok(())
    }
}

/// Double-buffered dictionary for concurrent serve-and-adapt (the serving
/// pipeline's swap discipline): a stable **read** snapshot that inference
/// consumes while the Eq. 51 update mutates the **write** buffer, with a
/// swap-and-resync [`Self::publish`] at batch boundaries. Inference never
/// blocks on the update, and the update never races a reader — the two
/// sides are distinct allocations whose roles exchange at the boundary.
#[derive(Clone, Debug)]
pub struct DictDoubleBuffer {
    read: DistributedDictionary,
    write: DistributedDictionary,
}

impl DictDoubleBuffer {
    /// Start with both sides holding `init`.
    pub fn new(init: DistributedDictionary) -> Self {
        DictDoubleBuffer { read: init.clone(), write: init }
    }

    /// The published snapshot (what inference reads).
    pub fn read(&self) -> &DistributedDictionary {
        &self.read
    }

    /// The adaptation side (what the Eq. 51 update writes).
    pub fn write_mut(&mut self) -> &mut DistributedDictionary {
        &mut self.write
    }

    /// Batch-boundary swap: the freshly-updated write buffer becomes the
    /// read snapshot, and the (now stale) old snapshot is resynced to serve
    /// as the next write buffer. One `M×K` copy, no allocation.
    pub fn publish(&mut self) {
        std::mem::swap(&mut self.read, &mut self.write);
        self.write
            .copy_from(&self.read)
            .expect("double buffer sides always share a shape");
    }

    /// Tear down, keeping the authoritative (write) side.
    pub fn into_write(self) -> DistributedDictionary {
        self.write
    }
}

/// Partition `k` atoms over `n` agents as evenly as possible.
fn even_blocks(k: usize, n: usize) -> Vec<(usize, usize)> {
    let base = k / n;
    let rem = k % n;
    let mut blocks = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let len = base + usize::from(i < rem);
        blocks.push((start, len));
        start += len;
    }
    blocks
}

/// Scale every column to unit ℓ2 norm (paper init: "columns are then
/// scaled to guarantee that the sub-unit-norm constraint is satisfied").
pub fn normalize_columns(w: &mut Mat) {
    let (m, k) = w.shape();
    let data = w.as_mut_slice();
    for q in 0..k {
        let mut nsq = 0.0f32;
        for r in 0..m {
            nsq += data[r * k + q] * data[r * k + q];
        }
        if nsq > 0.0 {
            let inv = 1.0 / nsq.sqrt();
            for r in 0..m {
                data[r * k + q] *= inv;
            }
        }
    }
}

/// Project all columns onto the constraint set (centralized baselines).
pub fn project_all_columns(w: &mut Mat, constraint: AtomConstraint) {
    let (m, k) = w.shape();
    match constraint {
        AtomConstraint::UnitBall => project_columns_unit_ball(w.as_mut_slice(), m, k),
        AtomConstraint::NonNegUnitBall => {
            project_columns_nonneg_unit_ball(w.as_mut_slice(), m, k)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_blocks_partition() {
        assert_eq!(even_blocks(10, 3), vec![(0, 4), (4, 3), (7, 3)]);
        assert_eq!(even_blocks(4, 4), vec![(0, 1), (1, 1), (2, 1), (3, 1)]);
        let blocks = even_blocks(7, 2);
        let total: usize = blocks.iter().map(|b| b.1).sum();
        assert_eq!(total, 7);
    }

    #[test]
    fn random_dictionary_unit_columns() {
        let mut rng = Pcg64::new(1);
        let d = DistributedDictionary::random(20, 8, 8, AtomConstraint::UnitBall, &mut rng).unwrap();
        for q in 0..8 {
            let n = crate::math::vector::norm2(&d.atom(q));
            assert!((n - 1.0).abs() < 1e-5, "atom {q} norm {n}");
        }
        assert_eq!(d.agents(), 8);
        assert_eq!(d.block(3), (3, 1));
    }

    #[test]
    fn nonneg_dictionary_nonneg() {
        let mut rng = Pcg64::new(2);
        let d =
            DistributedDictionary::random(10, 6, 3, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        assert!(d.mat().as_slice().iter().all(|&v| v >= 0.0));
        assert_eq!(d.block(0), (0, 2));
    }

    #[test]
    fn rejects_more_agents_than_atoms() {
        let mut rng = Pcg64::new(3);
        assert!(DistributedDictionary::random(5, 3, 4, AtomConstraint::UnitBall, &mut rng).is_err());
    }

    #[test]
    fn block_correlations_match_gemv() {
        let mut rng = Pcg64::new(4);
        let d = DistributedDictionary::random(12, 9, 3, AtomConstraint::UnitBall, &mut rng).unwrap();
        let nu: Vec<f32> = rng.normal_vec(12);
        let full = d.mat().matvec_t(&nu).unwrap();
        let mut out = vec![0.0; 9];
        for k in 0..3 {
            d.block_correlations(k, &nu, &mut out);
        }
        crate::testutil::assert_close(&out, &full, 1e-5, 1e-5);
    }

    #[test]
    fn block_accumulate_matches_matvec() {
        let mut rng = Pcg64::new(5);
        let d = DistributedDictionary::random(8, 6, 2, AtomConstraint::UnitBall, &mut rng).unwrap();
        let y: Vec<f32> = rng.normal_vec(6);
        let mut acc = vec![0.0; 8];
        for k in 0..2 {
            d.block_accumulate(k, &y, &mut acc);
        }
        let direct = d.mat().matvec(&y).unwrap();
        crate::testutil::assert_close(&acc, &direct, 1e-5, 1e-5);
    }

    #[test]
    fn batched_block_ops_bit_match_scalar() {
        let (m, kk, n, batch) = (12, 9, 3, 4);
        let mut rng = Pcg64::new(41);
        let d = DistributedDictionary::random(m, kk, n, AtomConstraint::UnitBall, &mut rng).unwrap();
        let nus: Vec<f32> = rng.normal_vec(batch * m);
        let mut batched = vec![0.0f32; batch * kk];
        let mut scalar = vec![0.0f32; kk];
        for k in 0..n {
            d.block_correlations_batched(k, &nus, batch, &mut batched);
            for s in 0..batch {
                d.block_correlations(k, &nus[s * m..(s + 1) * m], &mut scalar);
                let (start, len) = d.block(k);
                for q in start..start + len {
                    assert_eq!(batched[q * batch + s], scalar[q], "agent {k} atom {q} sample {s}");
                }
            }
        }
        // Accumulate with a sparse coefficient pattern (zeros must be
        // skipped identically on both paths).
        let mut coeff = vec![0.0f32; batch * kk];
        for (i, c) in coeff.iter_mut().enumerate() {
            if i % 3 == 0 {
                *c = rng.next_normal();
            }
        }
        let mut acc_b: Vec<f32> = rng.normal_vec(batch * m);
        let mut acc_s = acc_b.clone();
        for k in 0..n {
            d.block_accumulate_batched(k, &coeff, batch, &mut acc_b);
        }
        for s in 0..batch {
            let mut c_s = vec![0.0f32; kk];
            for q in 0..kk {
                c_s[q] = coeff[q * batch + s];
            }
            for k in 0..n {
                d.block_accumulate(k, &c_s, &mut acc_s[s * m..(s + 1) * m]);
            }
        }
        assert_eq!(acc_b, acc_s);
    }

    #[test]
    fn gradient_step_and_projection() {
        let mut rng = Pcg64::new(6);
        let mut d =
            DistributedDictionary::random(4, 2, 2, AtomConstraint::UnitBall, &mut rng).unwrap();
        let before = d.atom(0);
        let nu = vec![10.0, 0.0, 0.0, 0.0];
        let mut y = vec![0.0; 2];
        y[0] = 1.0;
        d.block_gradient_step(0, 1.0, &nu, &y);
        assert!((d.atom(0)[0] - (before[0] + 10.0)).abs() < 1e-5);
        // Atom 1 untouched (owned by agent 1, and y[1] = 0 anyway).
        d.project_block(0, AtomConstraint::UnitBall);
        assert!(crate::math::vector::norm2(&d.atom(0)) <= 1.0 + 1e-5);
    }

    #[test]
    fn copy_from_clones_atoms_and_checks_shape() {
        let mut rng = Pcg64::new(8);
        let src = DistributedDictionary::random(6, 4, 2, AtomConstraint::UnitBall, &mut rng)
            .unwrap();
        let mut dst =
            DistributedDictionary::random(6, 4, 2, AtomConstraint::UnitBall, &mut rng).unwrap();
        assert_ne!(dst.mat().as_slice(), src.mat().as_slice());
        dst.copy_from(&src).unwrap();
        assert_eq!(dst.mat().as_slice(), src.mat().as_slice());
        // Shape and partition mismatches are rejected.
        let other =
            DistributedDictionary::random(6, 4, 4, AtomConstraint::UnitBall, &mut rng).unwrap();
        assert!(dst.copy_from(&other).is_err(), "partition mismatch must fail");
        let bigger =
            DistributedDictionary::random(7, 4, 2, AtomConstraint::UnitBall, &mut rng).unwrap();
        assert!(dst.copy_from(&bigger).is_err(), "dimension mismatch must fail");
    }

    /// The double buffer's swap discipline: writes are invisible to the
    /// read snapshot until `publish`, and publish is swap + resync (the new
    /// write side starts from the just-published state).
    #[test]
    fn double_buffer_publish_swaps_and_resyncs() {
        let mut rng = Pcg64::new(9);
        let init =
            DistributedDictionary::random(5, 3, 3, AtomConstraint::UnitBall, &mut rng).unwrap();
        let mut buf = DictDoubleBuffer::new(init.clone());
        assert_eq!(buf.read().mat().as_slice(), init.mat().as_slice());

        // Mutate the write side: the read snapshot must be unaffected.
        buf.write_mut().mat_mut().as_mut_slice()[0] = 42.0;
        assert_eq!(buf.read().mat().as_slice(), init.mat().as_slice());

        // Publish: the update becomes visible, and the next write buffer
        // starts from the published state.
        buf.publish();
        assert_eq!(buf.read().mat().as_slice()[0], 42.0);
        assert_eq!(buf.write_mut().mat().as_slice()[0], 42.0);

        buf.write_mut().mat_mut().as_mut_slice()[1] = 7.0;
        buf.publish();
        assert_eq!(buf.read().mat().as_slice()[0], 42.0, "earlier update survives the swap");
        assert_eq!(buf.read().mat().as_slice()[1], 7.0);
        let last = buf.into_write();
        assert_eq!(last.mat().as_slice()[1], 7.0);
    }

    #[test]
    fn expand_preserves_existing_atoms() {
        let mut rng = Pcg64::new(7);
        let mut d =
            DistributedDictionary::random(6, 4, 4, AtomConstraint::NonNegUnitBall, &mut rng)
                .unwrap();
        let a0 = d.atom(0);
        d.expand(3, 3, AtomConstraint::NonNegUnitBall, &mut rng).unwrap();
        assert_eq!(d.k(), 7);
        assert_eq!(d.agents(), 7);
        crate::testutil::assert_close(&d.atom(0), &a0, 1e-7, 0.0);
        for q in 4..7 {
            let n = crate::math::vector::norm2(&d.atom(q));
            assert!((n - 1.0).abs() < 1e-5);
        }
        assert_eq!(d.block(4), (4, 1));
        assert_eq!(d.block(6), (6, 1));
    }
}
