//! Task specification: the `(f, h_y, h_W, W_k)` quadruple of paper Table I,
//! together with the conjugate-side quantities of Table II that the dual
//! diffusion algorithm actually evaluates.

use crate::ops::{
    huber_sum, s_conj, s_conj_plus, soft_threshold, soft_threshold_plus,
};

/// Constraint set `W_k` for dictionary atoms (Table I last column).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AtomConstraint {
    /// `‖w‖₂ ≤ 1` (Eq. 3 / projection Eq. 45).
    UnitBall,
    /// `‖w‖₂ ≤ 1, w ⪰ 0` (Eq. 4 / projection Eq. 47).
    NonNegUnitBall,
}

/// A dictionary-learning task instance from paper Table I/II.
///
/// Everything the diffusion inference needs is captured by four
/// ingredients:
/// * the threshold operator (`T_γ` two-sided for elastic net, `T⁺_γ`
///   one-sided for the non-negative elastic net),
/// * the conjugate-gradient scale `c_f` with `∇f*(ν) = c_f · ν`
///   (`1` for `f = ½‖u‖²`, `η` for Huber),
/// * the dual-domain box `V_f` (`∞` for squared-ℓ2, `‖ν‖_∞ ≤ 1` for Huber),
/// * the atom constraint set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TaskSpec {
    /// Sparse SVD / image denoising: `f = ½‖u‖²`, elastic net (Table I
    /// rows 1–2 with `h_W = 0`).
    SparseCoding { gamma: f32, delta: f32 },
    /// Non-negative matrix factorization / topic modeling: `f = ½‖u‖²`,
    /// non-negative elastic net (Table I row 3).
    Nmf { gamma: f32, delta: f32 },
    /// Huber-residual NMF (Table I row 4): `f = Σ L(uₘ)`.
    HuberNmf { gamma: f32, delta: f32, eta: f32 },
}

impl TaskSpec {
    /// ℓ1 weight γ.
    pub fn gamma(&self) -> f32 {
        match *self {
            TaskSpec::SparseCoding { gamma, .. }
            | TaskSpec::Nmf { gamma, .. }
            | TaskSpec::HuberNmf { gamma, .. } => gamma,
        }
    }

    /// ℓ2 weight δ.
    pub fn delta(&self) -> f32 {
        match *self {
            TaskSpec::SparseCoding { delta, .. }
            | TaskSpec::Nmf { delta, .. }
            | TaskSpec::HuberNmf { delta, .. } => delta,
        }
    }

    /// `c_f` in `∇f*(ν) = c_f · ν` (Table II column 3: `f* = ½‖ν‖²` or
    /// `(η/2)‖ν‖²`).
    pub fn conj_grad_scale(&self) -> f32 {
        match *self {
            TaskSpec::SparseCoding { .. } | TaskSpec::Nmf { .. } => 1.0,
            TaskSpec::HuberNmf { eta, .. } => eta,
        }
    }

    /// Box bound of `V_f` (Table II column 4), if any.
    pub fn dual_clip(&self) -> Option<f32> {
        match self {
            TaskSpec::SparseCoding { .. } | TaskSpec::Nmf { .. } => None,
            TaskSpec::HuberNmf { .. } => Some(1.0),
        }
    }

    /// Threshold operator `thr(·)` with level γ applied to `wᵀν`
    /// (`y° = thr(wᵀν)/δ`, Table II last column).
    #[inline]
    pub fn threshold(&self, s: f32) -> f32 {
        match *self {
            TaskSpec::SparseCoding { gamma, .. } => soft_threshold(s, gamma),
            TaskSpec::Nmf { gamma, .. } | TaskSpec::HuberNmf { gamma, .. } => {
                soft_threshold_plus(s, gamma)
            }
        }
    }

    /// Conjugate value `h*_k(Wᵀν)` given the pre-computed correlations
    /// `s = Wᵀν` (paper evaluates it as `S_{γ/δ}(s/δ)`).
    pub fn h_conj(&self, s: &[f32]) -> f32 {
        let scaled: Vec<f32> = s.iter().map(|&v| v / self.delta()).collect();
        match self {
            TaskSpec::SparseCoding { gamma, delta } => s_conj(&scaled, *gamma, *delta),
            TaskSpec::Nmf { gamma, delta } | TaskSpec::HuberNmf { gamma, delta, .. } => {
                s_conj_plus(&scaled, *gamma, *delta)
            }
        }
    }

    /// `f*(ν)` (Table II column 2).
    pub fn f_conj(&self, nu: &[f32]) -> f32 {
        let nsq = crate::math::vector::norm2_sq(nu);
        match *self {
            TaskSpec::SparseCoding { .. } | TaskSpec::Nmf { .. } => 0.5 * nsq,
            TaskSpec::HuberNmf { eta, .. } => 0.5 * eta * nsq,
        }
    }

    /// Primal residual loss `f(u)`.
    pub fn f_loss(&self, u: &[f32]) -> f32 {
        match *self {
            TaskSpec::SparseCoding { .. } | TaskSpec::Nmf { .. } => {
                0.5 * crate::math::vector::norm2_sq(u)
            }
            TaskSpec::HuberNmf { eta, .. } => huber_sum(u, eta),
        }
    }

    /// Regularizer value `h_y(y)` (elastic net or non-negative elastic net;
    /// returns `+∞` for infeasible non-negative arguments).
    pub fn h_reg(&self, y: &[f32]) -> f32 {
        let (gamma, delta) = (self.gamma(), self.delta());
        match self {
            TaskSpec::SparseCoding { .. } => {
                gamma * crate::math::vector::norm1(y)
                    + 0.5 * delta * crate::math::vector::norm2_sq(y)
            }
            TaskSpec::Nmf { .. } | TaskSpec::HuberNmf { .. } => {
                if y.iter().any(|&v| v < 0.0) {
                    f32::INFINITY
                } else {
                    gamma * y.iter().sum::<f32>()
                        + 0.5 * delta * crate::math::vector::norm2_sq(y)
                }
            }
        }
    }

    /// Atom constraint set for this task (Table I last column).
    pub fn atom_constraint(&self) -> AtomConstraint {
        match self {
            TaskSpec::SparseCoding { .. } => AtomConstraint::UnitBall,
            TaskSpec::Nmf { .. } | TaskSpec::HuberNmf { .. } => AtomConstraint::NonNegUnitBall,
        }
    }

    /// Gradient of the residual loss `f'_u(u)` — used by Eq. 50 checks.
    pub fn f_grad(&self, u: &[f32], out: &mut [f32]) {
        match *self {
            TaskSpec::SparseCoding { .. } | TaskSpec::Nmf { .. } => out.copy_from_slice(u),
            TaskSpec::HuberNmf { eta, .. } => {
                for (o, &v) in out.iter_mut().zip(u) {
                    *o = crate::ops::huber_grad(v, eta);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let t = TaskSpec::HuberNmf { gamma: 1.0, delta: 0.1, eta: 0.2 };
        assert_eq!(t.gamma(), 1.0);
        assert_eq!(t.delta(), 0.1);
        assert_eq!(t.conj_grad_scale(), 0.2);
        assert_eq!(t.dual_clip(), Some(1.0));
        assert_eq!(t.atom_constraint(), AtomConstraint::NonNegUnitBall);
        let s = TaskSpec::SparseCoding { gamma: 45.0, delta: 0.1 };
        assert_eq!(s.conj_grad_scale(), 1.0);
        assert_eq!(s.dual_clip(), None);
        assert_eq!(s.atom_constraint(), AtomConstraint::UnitBall);
    }

    #[test]
    fn threshold_dispatch() {
        let sc = TaskSpec::SparseCoding { gamma: 1.0, delta: 0.1 };
        assert_eq!(sc.threshold(-3.0), -2.0);
        let nmf = TaskSpec::Nmf { gamma: 1.0, delta: 0.1 };
        assert_eq!(nmf.threshold(-3.0), 0.0);
        assert_eq!(nmf.threshold(3.0), 2.0);
    }

    #[test]
    fn f_loss_and_conjugate_consistent() {
        // Fenchel–Young equality at ν = ∇f(u): f(u) + f*(ν) = uᵀν.
        let u = vec![0.3f32, -0.8, 1.2];
        for t in [
            TaskSpec::SparseCoding { gamma: 1.0, delta: 0.1 },
            TaskSpec::HuberNmf { gamma: 1.0, delta: 0.1, eta: 0.2 },
        ] {
            let mut nu = vec![0.0; 3];
            t.f_grad(&u, &mut nu);
            let lhs = t.f_loss(&u) + t.f_conj(&nu);
            let rhs = crate::math::blas::dot(&u, &nu);
            assert!((lhs - rhs).abs() < 1e-5, "{t:?}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn h_reg_infeasible_nonneg() {
        let nmf = TaskSpec::Nmf { gamma: 1.0, delta: 0.1 };
        assert!(nmf.h_reg(&[0.5, -0.1]).is_infinite());
        assert!(nmf.h_reg(&[0.5, 0.1]).is_finite());
    }

    /// `h*(Wᵀν) = sup_y [(Wᵀν)ᵀy − h(y)]`: check the closed form against a
    /// grid search in 1D.
    #[test]
    fn h_conj_matches_grid_supremum() {
        for t in [
            TaskSpec::SparseCoding { gamma: 0.7, delta: 0.3 },
            TaskSpec::Nmf { gamma: 0.7, delta: 0.3 },
        ] {
            for &a in &[-2.0f32, -0.4, 0.0, 0.5, 1.8] {
                let closed = t.h_conj(&[a]);
                let mut best = f32::NEG_INFINITY;
                for i in -4000..=4000 {
                    let y = i as f32 * 0.005;
                    let h = t.h_reg(&[y]);
                    if h.is_finite() {
                        best = best.max(a * y - h);
                    }
                }
                assert!((closed - best).abs() < 1e-3, "{t:?} a={a}: {closed} vs {best}");
            }
        }
    }
}
