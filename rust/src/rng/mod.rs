//! Deterministic random number generation substrate.
//!
//! No `rand` crate offline — this module provides a PCG-64 (PCG-XSL-RR)
//! generator plus the distributions the experiments need: uniform, normal
//! (Ziggurat-free Box–Muller), Dirichlet, categorical, and Fisher–Yates
//! shuffling. All experiment drivers take explicit seeds so every figure
//! is exactly reproducible.

pub mod dist;
pub mod pcg;

pub use dist::{Categorical, Dirichlet};
pub use pcg::Pcg64;
