//! PCG-64 (PCG-XSL-RR 128/64) pseudorandom generator.
//!
//! Reference: M. O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation" (2014).

const MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

/// PCG-XSL-RR 128/64 generator. 128-bit state, 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

impl Pcg64 {
    /// Seed with a stream derived from `seed` (fixed odd increment).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seed with an explicit stream id (the increment is forced odd).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(MULT).wrapping_add(rng.inc);
        // A few warm-up steps decorrelate small seeds.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent generator (for per-agent / per-worker streams).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Pcg64::with_stream(s, self.next_u64())
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform `f64` in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire rejection; unbiased).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below: n must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (uses two uniforms, caches nothing —
    /// branch-free and reproducible).
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; used by the Dirichlet sampler.
    pub fn next_gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.next_gamma(shape + 1.0);
            let u = self.next_f64().max(1e-300);
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.next_normal() as f64;
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x * x * x * x
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (reservoir-free partial
    /// shuffle; O(n) memory).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_normal()).collect()
    }

    /// Vector of iid uniform [0,1) samples.
    pub fn uniform_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::new(123);
        let mut b = Pcg64::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range_and_centered() {
        let mut rng = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut rng = Pcg64::new(11);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "p {p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut rng = Pcg64::new(17);
        for &shape in &[0.5f64, 1.0, 3.0] {
            let n = 20_000;
            let m: f64 = (0..n).map(|_| rng.next_gamma(shape)).sum::<f64>() / n as f64;
            assert!((m - shape).abs() < 0.1 * shape.max(0.5), "shape {shape}: mean {m}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(19);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely to be identity
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(23);
        let idx = rng.sample_indices(100, 10);
        assert_eq!(idx.len(), 10);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Pcg64::new(29);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
