//! Distributions built on [`Pcg64`]: Dirichlet and categorical sampling.
//!
//! These drive the synthetic topic-model corpus generator
//! ([`crate::data::corpus`]) that substitutes for the LDC-licensed TDT2
//! dataset.

use super::Pcg64;

/// Dirichlet distribution over the simplex.
#[derive(Clone, Debug)]
pub struct Dirichlet {
    alpha: Vec<f64>,
}

impl Dirichlet {
    /// Symmetric Dirichlet with concentration `alpha` over `k` categories.
    pub fn symmetric(k: usize, alpha: f64) -> Self {
        assert!(k > 0 && alpha > 0.0);
        Dirichlet { alpha: vec![alpha; k] }
    }

    /// General Dirichlet.
    pub fn new(alpha: Vec<f64>) -> Self {
        assert!(!alpha.is_empty() && alpha.iter().all(|&a| a > 0.0));
        Dirichlet { alpha }
    }

    /// Draw a probability vector.
    pub fn sample(&self, rng: &mut Pcg64) -> Vec<f64> {
        let mut g: Vec<f64> = self.alpha.iter().map(|&a| rng.next_gamma(a)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            // Degenerate draw (possible with tiny alpha): fall back to uniform.
            let k = g.len() as f64;
            return vec![1.0 / k; g.len()];
        }
        for v in &mut g {
            *v /= s;
        }
        g
    }
}

/// Categorical sampler with O(log k) draws via cumulative sums.
#[derive(Clone, Debug)]
pub struct Categorical {
    cdf: Vec<f64>,
}

impl Categorical {
    /// Build from (unnormalized) non-negative weights.
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0, "categorical weight must be non-negative");
            acc += w;
            cdf.push(acc);
        }
        assert!(acc > 0.0, "categorical: all weights zero");
        for v in &mut cdf {
            *v /= acc;
        }
        Categorical { cdf }
    }

    /// Draw a category index.
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        // Binary search for the first cdf entry >= u.
        match self
            .cdf
            .binary_search_by(|v| v.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dirichlet_on_simplex() {
        let d = Dirichlet::symmetric(5, 0.7);
        let mut rng = Pcg64::new(31);
        for _ in 0..100 {
            let p = d.sample(&mut rng);
            assert_eq!(p.len(), 5);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn dirichlet_mean_matches_alpha() {
        let d = Dirichlet::new(vec![2.0, 1.0, 1.0]);
        let mut rng = Pcg64::new(37);
        let n = 20_000;
        let mut m = [0.0f64; 3];
        for _ in 0..n {
            let p = d.sample(&mut rng);
            for i in 0..3 {
                m[i] += p[i];
            }
        }
        for v in &mut m {
            *v /= n as f64;
        }
        assert!((m[0] - 0.5).abs() < 0.01, "{m:?}");
        assert!((m[1] - 0.25).abs() < 0.01, "{m:?}");
    }

    #[test]
    fn categorical_frequencies() {
        let c = Categorical::new(&[1.0, 3.0]);
        let mut rng = Pcg64::new(41);
        let n = 40_000;
        let ones = (0..n).filter(|_| c.sample(&mut rng) == 1).count();
        let p = ones as f64 / n as f64;
        assert!((p - 0.75).abs() < 0.01, "p {p}");
    }

    #[test]
    fn categorical_zero_weight_never_drawn() {
        let c = Categorical::new(&[0.0, 1.0, 0.0]);
        let mut rng = Pcg64::new(43);
        for _ in 0..1000 {
            assert_eq!(c.sample(&mut rng), 1);
        }
    }
}
