//! Fig. 4 — step-size tuning learning curves (§IV-A).
//!
//! Reproduces the paper's tuning procedure for the Huber document-
//! detection setup: exact `(y°, ν°)` from the FISTA solver (the CVX
//! stand-in), then per-iteration SNR of the distributed primal and dual
//! estimates at μ = 0.5. The paper's observations to reproduce:
//! (i) both curves rise to a high SNR plateau; (ii) the primal `y`
//! reaches a high SNR before the dual ν.
//!
//! Output: `results/fig4_learning_curve.csv` (iter, y_snr_db, nu_snr_db).

use ddl::cli::Args;
use ddl::coordinator::csv::write_csv;
use std::path::Path;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap();
    let mu = args.f32_or("mu", 0.5).unwrap();
    let iters = args.usize_or("iters", 1000).unwrap();
    let seed = args.u64_or("seed", 7).unwrap();

    println!("Fig. 4: SNR learning curves (Huber novelty setup, mu = {mu})");
    let pts = match ddl::coordinator::tuning::tuning_curves(mu, iters, seed) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    };

    let rows: Vec<Vec<f64>> = pts
        .iter()
        .map(|p| vec![p.iter as f64, p.y_snr_db, p.nu_snr_db])
        .collect();
    write_csv(Path::new("results/fig4_learning_curve.csv"), &["iter", "y_snr_db", "nu_snr_db"], &rows)
        .unwrap();

    println!("{:>6} {:>10} {:>10}", "iter", "y SNR dB", "nu SNR dB");
    for p in pts.iter().step_by((iters / 20).max(1)) {
        println!("{:>6} {:>10.2} {:>10.2}", p.iter, p.y_snr_db, p.nu_snr_db);
    }
    let last = pts.last().unwrap();
    println!("\nfinal: y {:.1} dB, nu {:.1} dB", last.y_snr_db, last.nu_snr_db);

    // Paper shape check: primal leads the dual on the way up.
    let mid = &pts[pts.len() / 4];
    println!(
        "at iteration {}: y leads nu by {:.1} dB (paper: primal converges first)",
        mid.iter,
        mid.y_snr_db - mid.nu_snr_db
    );
    println!("wrote results/fig4_learning_curve.csv");
}
