//! Fig. 5 — image denoising via model-distributed dictionary learning
//! (§IV-B). **The end-to-end headline driver**: trains the distributed
//! dictionary online over the agent network, denoises a σ=50-corrupted
//! scene, and compares against the centralized comparator [6], in both
//! data configurations:
//!
//! * all agents informed (Fig. 5h/i + the per-agent PSNR sweep 5g);
//! * only agent 1 informed (Fig. 5e/f).
//!
//! Paper numbers (van Hateren scenes, N = 196, 1M patches):
//! corrupted 14.06 dB → [6] 21.77 dB, distributed 21.97/21.98 dB.
//! Scaled defaults here (synthetic scenes, N = 64, ~12k patch
//! presentations) reproduce the *shape*: distributed ≈ centralized ≫
//! corrupted, uniform across agents, single-informed ≈ all-informed.
//!
//! Outputs: results/fig5_psnr.csv, results/fig5_per_agent_psnr.csv,
//! results/fig5_{clean,noisy,denoised}.pgm, results/fig5_atoms.csv
//!
//! Flags: --quick (smaller run), --paper-scale, --skip-single.

use ddl::cli::Args;
use ddl::config::experiment::DenoiseConfig;
use ddl::coordinator::csv::{write_csv, write_labeled_csv};
use ddl::coordinator::run_denoise;
use std::path::Path;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap();
    let mut cfg = if args.flag("paper-scale") {
        DenoiseConfig::paper_scale()
    } else {
        DenoiseConfig::default()
    };
    if args.flag("quick") {
        cfg.agents = 32;
        cfg.train_samples = 2_000;
        cfg.train_infer.iters = 120;
        cfg.denoise_infer.iters = 150;
        cfg.image_side = 96;
        cfg.denoise_stride = 3;
    }
    cfg.seed = args.u64_or("seed", cfg.seed).unwrap();

    println!("Fig. 5: image denoising (N = {} agents, M = {})", cfg.agents, cfg.patch * cfg.patch);

    // --- configuration A: all agents informed, with baseline + per-agent ---
    println!("\n[A] all agents informed (Fig. 5g/h/i)");
    let report_all = run_denoise(&cfg, true, true, |s| println!("  {s}")).unwrap();

    // --- configuration B: only agent 1 informed (Fig. 5e/f) ---
    let report_single = if args.flag("skip-single") {
        None
    } else {
        println!("\n[B] only agent 1 informed (Fig. 5e/f)");
        let mut cfg_single = cfg.clone();
        cfg_single.informed = Some(1);
        Some(run_denoise(&cfg_single, false, false, |s| println!("  {s}")).unwrap())
    };

    // --- report ---
    println!("\n== Fig. 5 PSNR summary (paper: 14.06 / 21.77 / 21.97 / 21.98 dB) ==");
    println!("corrupted:                {:.2} dB", report_all.psnr_noisy);
    println!(
        "centralized [6]:          {:.2} dB",
        report_all.psnr_centralized.unwrap_or(f64::NAN)
    );
    if let Some(rs) = &report_single {
        println!("distributed (1 informed): {:.2} dB", rs.psnr_distributed);
    }
    println!("distributed (all):        {:.2} dB", report_all.psnr_distributed);

    let mut rows = vec![
        ("corrupted".to_string(), vec![report_all.psnr_noisy]),
        (
            "centralized".to_string(),
            vec![report_all.psnr_centralized.unwrap_or(f64::NAN)],
        ),
        ("distributed_all".to_string(), vec![report_all.psnr_distributed]),
    ];
    if let Some(rs) = &report_single {
        rows.push(("distributed_single".to_string(), vec![rs.psnr_distributed]));
    }
    write_labeled_csv(Path::new("results/fig5_psnr.csv"), &["config", "psnr_db"], &rows).unwrap();

    // Per-agent PSNR (Fig. 5g): uniformity across the network.
    if !report_all.per_agent_psnr.is_empty() {
        let pa: Vec<Vec<f64>> = report_all
            .per_agent_psnr
            .iter()
            .enumerate()
            .map(|(k, &p)| vec![k as f64, p])
            .collect();
        write_csv(Path::new("results/fig5_per_agent_psnr.csv"), &["agent", "psnr_db"], &pa)
            .unwrap();
        let min = report_all.per_agent_psnr.iter().cloned().fold(f64::MAX, f64::min);
        let max = report_all.per_agent_psnr.iter().cloned().fold(f64::MIN, f64::max);
        println!(
            "per-agent PSNR (Fig. 5g): {:.2}–{:.2} dB (spread {:.2} dB — paper: 'relatively uniform')",
            min,
            max,
            max - min
        );
    }

    // Images + learned atoms for eyeballing.
    let (clean, noisy, denoised) = &report_all.images;
    clean.write_pgm(Path::new("results/fig5_clean.pgm")).unwrap();
    noisy.write_pgm(Path::new("results/fig5_noisy.pgm")).unwrap();
    denoised.write_pgm(Path::new("results/fig5_denoised.pgm")).unwrap();
    let dict = &report_all.dictionary;
    let atom_rows: Vec<Vec<f64>> = (0..dict.cols())
        .map(|q| dict.col(q).iter().map(|&v| v as f64).collect())
        .collect();
    write_csv(
        Path::new("results/fig5_atoms.csv"),
        &vec!["px"; dict.rows()],
        &atom_rows,
    )
    .unwrap();
    println!("wrote results/fig5_* (psnr csv, per-agent csv, pgm images, atoms)");
}
