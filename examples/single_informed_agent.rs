//! Ablation: informed-agent subsets and topologies.
//!
//! The paper's striking property (Fig. 5e/f and §II-B): agents that never
//! see the data still drive the inference to the global solution — only
//! the dual variable diffuses. This driver quantifies it directly at the
//! inference level (no training), sweeping:
//!
//! * |N_I| ∈ {1, N/4, N} informed agents — solution error vs the exact
//!   dual optimum stays flat;
//! * topology ∈ {ring, G(N,0.2), G(N,0.5), complete} — mixing speed
//!   (spectral gap) governs how many iterations consensus needs.
//!
//! Output: results/ablation_informed.csv, results/ablation_topology.csv

use ddl::cli::Args;
use ddl::coordinator::csv::write_labeled_csv;
use ddl::graph::{laplacian::spectral_gap, metropolis_weights, Graph, Topology};
use ddl::infer::{exact_dual, DiffusionEngine, DiffusionParams};
use ddl::model::{AtomConstraint, DistributedDictionary, TaskSpec};
use ddl::rng::Pcg64;
use std::path::Path;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap();
    let n = args.usize_or("agents", 32).unwrap();
    let m = args.usize_or("dim", 64).unwrap();
    let seed = args.u64_or("seed", 5).unwrap();
    let iters = args.usize_or("iters", 4000).unwrap();
    let mu = args.f32_or("mu", 0.05).unwrap();

    let mut rng = Pcg64::new(seed);
    let dict = DistributedDictionary::random(m, n, n, AtomConstraint::UnitBall, &mut rng).unwrap();
    let task = TaskSpec::SparseCoding { gamma: 0.2, delta: 0.3 };
    let x = rng.normal_vec(m);
    let exact = exact_dual(&dict, &task, &x, 1e-9, 50_000).unwrap();

    println!("== informed-agent sweep (N = {n}, G(N, 0.5)) ==");
    let g = Graph::generate(n, &Topology::ErdosRenyi { p: 0.5 }, &mut rng);
    let a = metropolis_weights(&g);
    let mut rows = Vec::new();
    for (label, informed) in [
        ("all", None),
        ("quarter", Some((0..n / 4).collect::<Vec<_>>())),
        ("single", Some(vec![0usize])),
    ] {
        let mut eng = DiffusionEngine::new(&a, m, informed.as_deref()).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams::new(mu, iters)).unwrap();
        let nu = eng.consensus_nu();
        let err = ddl::math::vector::dist_sq(&nu, &exact.nu).sqrt()
            / ddl::math::vector::norm2(&exact.nu);
        let informed_count = informed.map(|v| v.len()).unwrap_or(n);
        println!("  |N_I| = {informed_count:>3}: relative dual error {err:.3e}");
        rows.push((label.to_string(), vec![informed_count as f64, err as f64]));
    }
    write_labeled_csv(
        Path::new("results/ablation_informed.csv"),
        &["config", "informed", "rel_error"],
        &rows,
    )
    .unwrap();

    println!("\n== topology sweep (all informed) ==");
    let mut rows = Vec::new();
    for (label, topo) in [
        ("ring", Topology::Ring { k: 1 }),
        ("er_p02", Topology::ErdosRenyi { p: 0.2 }),
        ("er_p05", Topology::ErdosRenyi { p: 0.5 }),
        ("complete", Topology::FullyConnected),
    ] {
        let g = Graph::generate(n, &topo, &mut rng);
        let a = metropolis_weights(&g);
        let gap = spectral_gap(&a);
        let mut eng = DiffusionEngine::new(&a, m, None).unwrap();
        eng.run(&dict, &task, &x, DiffusionParams::new(mu, iters)).unwrap();
        let nu = eng.consensus_nu();
        let err = ddl::math::vector::dist_sq(&nu, &exact.nu).sqrt()
            / ddl::math::vector::norm2(&exact.nu);
        let dis = eng.disagreement();
        println!(
            "  {label:<9} spectral gap {gap:.3}: rel error {err:.3e}, disagreement {dis:.3e}"
        );
        rows.push((label.to_string(), vec![gap as f64, err as f64, dis as f64]));
    }
    write_labeled_csv(
        Path::new("results/ablation_topology.csv"),
        &["topology", "spectral_gap", "rel_error", "disagreement"],
        &rows,
    )
    .unwrap();
    println!("\nwrote results/ablation_informed.csv, results/ablation_topology.csv");
}
