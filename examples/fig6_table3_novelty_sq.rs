//! Fig. 6 + Table III — novel document detection, squared-ℓ2 residual
//! (§IV-C1).
//!
//! Streams topic batches over 8 time-steps; at each step, scores a fixed
//! held-out test set (all 30 topics present), trains on the incoming
//! batch, and expands the dictionary/network by 10 atoms. Compares:
//! centralized [6] (Mairal), diffusion fully-connected, and diffusion
//! over a sparse random topology.
//!
//! Paper shape to reproduce (Table III): [6] wins the first ~2 steps then
//! degrades (0.97 → 0.55); both diffusion variants hold ≈0.9 throughout.
//!
//! Outputs: results/table3_auc.csv, results/fig6_roc_s<step>_<algo>.csv

use ddl::cli::Args;
use ddl::config::experiment::NoveltyConfig;
use ddl::coordinator::csv::write_labeled_csv;
use ddl::coordinator::{run_novelty, NoveltyAlgo};
use ddl::metrics::roc::write_roc_csv;
use std::path::Path;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap();
    let mut cfg = NoveltyConfig::squared_l2();
    if args.flag("quick") {
        cfg.vocab = 300;
        cfg.batch_docs = 120;
        cfg.dist_iters = 150;
        cfg.fc_iters = 60;
        cfg.time_steps = 4;
    }
    cfg.seed = args.u64_or("seed", cfg.seed).unwrap();
    cfg.time_steps = args.usize_or("steps", cfg.time_steps).unwrap();

    println!(
        "Fig. 6 / Table III: novelty detection, squared-l2 (vocab {}, {} topics, {} steps)",
        cfg.vocab, cfg.topics, cfg.time_steps
    );
    let algos = [
        NoveltyAlgo::CentralizedMairal,
        NoveltyAlgo::DiffusionFullyConnected,
        NoveltyAlgo::Diffusion,
    ];
    let report = run_novelty(&cfg, &algos, |s| println!("  {s}")).unwrap();

    // Table III layout: step × algorithm.
    println!("\n== Table III (AUC; paper: [6] 0.97→0.55, diffusion ≈0.9) ==");
    println!("{:<6} {:<10} {:<12} {:<10}", "step", "mairal[6]", "diff (FC)", "diffusion");
    let mut csv_rows = Vec::new();
    for s in 1..=cfg.time_steps {
        let get = |algo: &str| {
            report
                .steps
                .iter()
                .find(|r| r.step == s && r.algo == algo)
                .map(|r| r.auc)
        };
        if let (Some(m), Some(fc), Some(d)) = (get("mairal"), get("diffusion_fc"), get("diffusion")) {
            println!("{s:<6} {m:<10.3} {fc:<12.3} {d:<10.3}");
            csv_rows.push((format!("{s}"), vec![m, fc, d]));
        }
    }
    write_labeled_csv(
        Path::new("results/table3_auc.csv"),
        &["step", "mairal", "diffusion_fc", "diffusion"],
        &csv_rows,
    )
    .unwrap();

    for r in &report.steps {
        let path = format!("results/fig6_roc_s{}_{}.csv", r.step, r.algo);
        write_roc_csv(Path::new(&path), &r.roc).unwrap();
    }
    println!("\nwrote results/table3_auc.csv and results/fig6_roc_s*_*.csv");

    // Shape check vs the paper.
    let late_steps: Vec<usize> = (3..=cfg.time_steps).collect();
    let mut diff_wins = 0;
    let mut total = 0;
    for &s in &late_steps {
        let m = report.steps.iter().find(|r| r.step == s && r.algo == "mairal");
        let d = report.steps.iter().find(|r| r.step == s && r.algo == "diffusion");
        if let (Some(m), Some(d)) = (m, d) {
            total += 1;
            if d.auc >= m.auc {
                diff_wins += 1;
            }
        }
    }
    println!(
        "diffusion ≥ centralized on {diff_wins}/{total} of steps ≥3 (paper: all of them)"
    );
}
