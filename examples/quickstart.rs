//! Quickstart: verify the whole three-layer stack composes.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the AOT HLO artifact (L1 Pallas kernel fused into the L2 jax
//! graph), executes it through PJRT from rust (L3), cross-checks against
//! the native engine, and applies one dictionary update.

use std::path::Path;

fn main() {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    match ddl::coordinator::quickstart::run_quickstart(Path::new(&dir), &mut |s| println!("{s}")) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("quickstart failed: {e}");
            std::process::exit(1);
        }
    }
}
