//! Fig. 7 + Table IV — novel document detection with the Huber residual
//! (§IV-C2).
//!
//! Same streaming protocol as Fig. 6 but: Huber loss (η = 0.2, dual box
//! `‖ν‖∞ ≤ 1` enforced by projected diffusion), γ = 1, evaluation on the
//! *incoming* batch, and novel topics appear only at time-steps
//! 1, 2, 5, 6, 8 (the paper's ordered-data schedule) — so ROC curves are
//! produced only at those steps. Comparator: centralized ADMM ℓ1
//! dictionary learning [11] on ℓ1-normalized data.
//!
//! Paper shape (Table IV): diffusion ≈0.79–0.96 ≫ ADMM ≈0.61–0.73;
//! sparse topology ≈ fully connected (±0.01).
//!
//! Outputs: results/table4_auc.csv, results/fig7_roc_s<step>_<algo>.csv

use ddl::cli::Args;
use ddl::config::experiment::NoveltyConfig;
use ddl::coordinator::csv::write_labeled_csv;
use ddl::coordinator::{run_novelty, NoveltyAlgo};
use ddl::metrics::roc::write_roc_csv;
use std::path::Path;

fn main() {
    let args = Args::parse(std::env::args().skip(1)).unwrap();
    let mut cfg = NoveltyConfig::huber();
    if args.flag("quick") {
        cfg.vocab = 300;
        cfg.batch_docs = 120;
        cfg.dist_iters = 150;
        cfg.fc_iters = 60;
    }
    cfg.seed = args.u64_or("seed", cfg.seed).unwrap();
    cfg.time_steps = args.usize_or("steps", cfg.time_steps).unwrap();

    println!(
        "Fig. 7 / Table IV: novelty detection, Huber residual (η=0.2, γ={}, vocab {})",
        cfg.gamma, cfg.vocab
    );
    println!("(novel topics only at steps 1, 2, 5, 6, 8 — others produce no ROC)");
    let algos = [
        NoveltyAlgo::CentralizedAdmm,
        NoveltyAlgo::DiffusionFullyConnected,
        NoveltyAlgo::Diffusion,
    ];
    let report = run_novelty(&cfg, &algos, |s| println!("  {s}")).unwrap();

    println!("\n== Table IV (AUC; paper: ADMM ~0.61-0.73, diffusion ~0.79-0.96) ==");
    println!("{:<6} {:<10} {:<12} {:<10}", "step", "admm[11]", "diff (FC)", "diffusion");
    let mut csv_rows = Vec::new();
    for s in 1..=cfg.time_steps {
        let get = |algo: &str| {
            report
                .steps
                .iter()
                .find(|r| r.step == s && r.algo == algo)
                .map(|r| r.auc)
        };
        if let (Some(a), Some(fc), Some(d)) = (get("admm"), get("diffusion_fc"), get("diffusion")) {
            println!("{s:<6} {a:<10.3} {fc:<12.3} {d:<10.3}");
            csv_rows.push((format!("{s}"), vec![a, fc, d]));
        }
    }
    write_labeled_csv(
        Path::new("results/table4_auc.csv"),
        &["step", "admm", "diffusion_fc", "diffusion"],
        &csv_rows,
    )
    .unwrap();

    for r in &report.steps {
        let path = format!("results/fig7_roc_s{}_{}.csv", r.step, r.algo);
        write_roc_csv(Path::new(&path), &r.roc).unwrap();
    }
    println!("\nwrote results/table4_auc.csv and results/fig7_roc_s*_*.csv");

    // Shape checks.
    let mut d_beats_admm = 0;
    let mut total = 0;
    for row in &csv_rows {
        total += 1;
        if row.1[2] > row.1[0] {
            d_beats_admm += 1;
        }
    }
    println!("diffusion > ADMM on {d_beats_admm}/{total} evaluated steps (paper: all)");
}
