//! Streaming service demo: a ring of N = 100 agents serves a live stream
//! of 10×10 patches while the dictionary adapts online (paper Alg. 1 —
//! every sample is presented to the network exactly once).
//!
//! ```bash
//! cargo run --release --example streaming_service
//! ```
//!
//! Requests arrive at a finite rate, the micro-batching queue closes
//! minibatches by max-size (B = 8) or max-wait (2 ms), and each batch is
//! one `DiffusionEngine::run_batch` sweep followed by the Eq. 51 update.
//! The report shows throughput, latency percentiles, the ψ traffic the
//! equivalent message-passing deployment would ship, and the
//! representation loss falling while the service runs — the paper's
//! online-learning property, live under load.

use ddl::config::experiment::{InferenceConfig, ServeConfig};

fn main() {
    let base = ServeConfig::default();
    let cfg = ServeConfig {
        seed: 0x57_2E_A3,
        agents: 100,
        dim: 100,
        topology: "ring".into(),
        ring_k: 2,
        batch: 8,
        max_wait_us: 2_000,
        samples: 384,
        // Finite arrival rate: the queue alternates between full batches
        // and deadline-released partial ones.
        rate: 1_500.0,
        mu_w: 0.05,
        infer: InferenceConfig { mu: 0.4, iters: 120, gamma: 0.08, delta: 0.2, threads: 2 },
        ..base
    };

    match ddl::serve::run_service(&cfg, &mut |s| println!("{s}")) {
        Ok(report) => {
            println!("\n== streaming service report (ring, N = {}) ==", cfg.agents);
            println!("{}", report.summary(cfg.agents));
            println!(
                "\nonline adaptation: loss {:.4} -> {:.4} ({:.1}% lower while serving)",
                report.loss_first_quarter,
                report.loss_last_quarter,
                100.0 * (1.0 - report.loss_last_quarter / report.loss_first_quarter.max(1e-12)),
            );
        }
        Err(e) => {
            eprintln!("streaming_service failed: {e}");
            std::process::exit(1);
        }
    }

    // Pipelined vs serial at *saturation* (rate = 0 for both, so the
    // serial session's virtual clock is pure measured compute and the
    // comparison is apples-to-apples): the three-stage pipeline overlaps
    // batch formation, diffusion inference (two batches in flight), and
    // the Eq. 51 update on separate threads (`ddl serve --pipeline`).
    let sat_cfg = ddl::config::experiment::ServeConfig { rate: 0.0, ..cfg.clone() };
    let pipe_cfg = ddl::config::experiment::ServeConfig { pipeline: true, ..sat_cfg.clone() };
    let serial = match ddl::serve::run_service(&sat_cfg, &mut |_| {}) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("streaming_service (saturated serial) failed: {e}");
            std::process::exit(1);
        }
    };
    match ddl::serve::run_service(&pipe_cfg, &mut |s| println!("{s}")) {
        Ok(pipe) => {
            println!(
                "\n== pipelined (depth {}, saturated) ==\n{}",
                pipe.pipeline_depth,
                pipe.summary(pipe_cfg.agents)
            );
            println!(
                "\npipelined vs serial peak throughput: {:.1} vs {:.1} samples/s ({:.2}x)",
                pipe.throughput_rps,
                serial.throughput_rps,
                pipe.throughput_rps / serial.throughput_rps.max(1e-12),
            );
        }
        Err(e) => {
            eprintln!("streaming_service (pipelined) failed: {e}");
            std::process::exit(1);
        }
    }
}
