"""L2: the jax compute graphs that get AOT-lowered to HLO artifacts.

Each graph fuses the *entire* per-sample inference loop (``lax.fori_loop``
over the L1 Pallas diffusion step) plus primal recovery into a single
executable, so the rust request path never crosses the host boundary
mid-inference. Variants:

* ``infer_sq``     — squared-l2 residual, two-sided T_gamma (denoising);
* ``infer_nmf``    — squared-l2, one-sided T^+ (novelty, Fig. 6);
* ``infer_huber``  — Huber residual, one-sided T^+, l-inf box (Fig. 7);
* ``dict_update``  — Eq. 51 atom update + constraint projection;
* ``novelty_cost`` — the dual-cost novelty score (Eqs. 59/63-66).

All graphs take the transposed dictionary ``Wt (N, M)`` (row k = atom of
agent k; one atom per agent as in the paper's experiments), the combine
matrix transposed ``At (N, N)``, the informed mask ``theta (N,)`` and a
packed scalar ``params (8,)`` operand (see kernels/diffusion.py), so one
artifact per (shape, variant, iteration count) serves all hyperparameter
settings.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import diffusion as K
from .kernels import ref as R


def _variant_flags(variant: str):
    if variant == "sq":
        return dict(onesided=False, clip=False)
    if variant == "nmf":
        return dict(onesided=True, clip=False)
    if variant == "huber":
        return dict(onesided=True, clip=True)
    raise ValueError(f"unknown variant {variant!r}")


def make_inference(variant: str, iters: int, *, use_pallas: bool = True, block_n: int = 64):
    """Build the fused inference function ``(wt, x, at, theta, params) ->
    (V, y)`` with a fixed iteration count (lowered into one fori_loop)."""
    flags = _variant_flags(variant)

    def infer(wt, x, at, theta, params):
        n, m = wt.shape
        v0 = jnp.zeros((n, m), dtype=wt.dtype)

        if use_pallas:
            step = functools.partial(
                K.diffusion_step, block_n=block_n, interpret=True, **flags
            )
        else:
            step = functools.partial(R.diffusion_step, **flags)

        def body(_, v):
            return step(v, wt, x, at, theta, params)

        v = jax.lax.fori_loop(0, iters, body, v0)
        if use_pallas:
            y = K.recover_y(v, wt, params, block_n=block_n, interpret=True,
                            onesided=flags["onesided"])
        else:
            y = R.recover_y(v, wt, params, onesided=flags["onesided"])
        return v, y

    return infer


def dict_update(wt, nu, y, mu_w, *, nonneg: bool):
    """Eq. 51: ``w_k <- Pi(w_k + mu_w y_k nu)`` for every agent, with the
    unit-ball (or non-negative unit-ball) projection of Eqs. 45/47.

    ``nu (M,)`` is each agent's converged dual estimate (the rust driver
    passes per-agent rows when minibatching).
    """
    w_new = wt + mu_w * y[:, None] * nu[None, :]
    if nonneg:
        w_new = jnp.maximum(w_new, 0.0)
    norms = jnp.sqrt(jnp.sum(w_new * w_new, axis=1, keepdims=True))
    scale = jnp.where(norms > 1.0, 1.0 / jnp.maximum(norms, 1e-12), 1.0)
    return w_new * scale


def novelty_cost(wt, v, x, params, *, variant: str):
    """Novelty score ``-g = sum_k J_k(nu; x)`` (higher = worse fit = more
    novel). Per-agent h* terms use each agent's own dual row; the f* and
    data terms use the network-average nu (all-informed configuration,
    Eq. 59). The 1/N scaling is absorbed into the detection threshold.
    """
    flags = _variant_flags(variant)
    gamma, delta = params[1], params[2]
    cf = params[3] * wt.shape[0]  # cf_over_n * N = c_f (eta or 1)
    nu_bar = jnp.mean(v, axis=0)
    s = jnp.sum(wt * v, axis=1) / delta  # per-agent w_k^T nu_k / delta
    t = R.threshold(s, gamma / delta, onesided=flags["onesided"])
    # S_{gamma/delta}(s) per agent (Table II footnotes b/d), summed.
    h_conj = jnp.sum(-0.5 * delta * t * t - gamma * jnp.abs(t) + delta * s * t)
    f_conj = 0.5 * cf * jnp.sum(nu_bar * nu_bar)
    # score = g(nu) = -(sum_k J_k); by strong duality the primal optimum.
    return -(f_conj - jnp.dot(nu_bar, x) + h_conj)


def make_infer_with_cost(variant: str, iters: int, *, use_pallas: bool = True,
                         block_n: int = 64):
    """Inference + novelty score in one artifact (the novelty serving
    path): ``(wt, x, at, theta, params) -> (V, y, cost)``."""
    infer = make_inference(variant, iters, use_pallas=use_pallas, block_n=block_n)

    def run(wt, x, at, theta, params):
        v, y = infer(wt, x, at, theta, params)
        return v, y, novelty_cost(wt, v, x, params, variant=variant)

    return run


def make_dict_update(*, nonneg: bool):
    """Wrap dict_update for AOT export: ``(wt, nu, y, mu_w) -> wt'``."""

    def run(wt, nu, y, mu_w):
        return dict_update(wt, nu, y, mu_w, nonneg=nonneg)

    return run
