"""AOT export: lower the L2 graphs to HLO *text* artifacts for the rust
PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/): ``python -m compile.aot --out-dir ../artifacts``

Emits one ``<name>.hlo.txt`` per artifact plus ``manifest.json`` recording
shapes, variants, and iteration counts — parsed by rust/src/runtime.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side unwraps with to_tuple{1,2,3})."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# Artifact table: name -> (builder, shapes, metadata).
# One atom per agent (K = N) on the HLO path, as in the paper's experiments.
def artifact_specs(scale: str):
    """Artifact definitions. `scale` picks the shape preset."""
    presets = {
        "default": dict(
            denoise=dict(m=100, n=64, train_iters=200, denoise_iters=300),
            novelty=dict(m=800, n=40, iters=150),
            quickstart=dict(m=16, n=8, iters=60),
        ),
        "tiny": dict(  # CI-fast preset used by pytest
            denoise=dict(m=16, n=6, train_iters=20, denoise_iters=25),
            novelty=dict(m=24, n=5, iters=15),
            quickstart=dict(m=16, n=8, iters=60),
        ),
    }
    p = presets[scale]
    dn, nv, qs = p["denoise"], p["novelty"], p["quickstart"]

    specs = {}

    def infer_spec(name, variant, m, n, iters, with_cost):
        build = (
            model.make_infer_with_cost(variant, iters)
            if with_cost
            else model.make_inference(variant, iters)
        )
        specs[name] = dict(
            build=build,
            args=[f32(n, m), f32(m), f32(n, n), f32(n), f32(8)],
            meta=dict(
                kind="infer",
                variant=variant,
                m=m,
                n=n,
                iters=iters,
                with_cost=with_cost,
                inputs=["wt", "x", "at", "theta", "params"],
                outputs=["v", "y"] + (["cost"] if with_cost else []),
            ),
        )

    infer_spec("denoise_infer", "sq", dn["m"], dn["n"], dn["train_iters"], False)
    infer_spec("denoise_infer_long", "sq", dn["m"], dn["n"], dn["denoise_iters"], False)
    infer_spec("novelty_sq_infer", "nmf", nv["m"], nv["n"], nv["iters"], True)
    infer_spec("novelty_huber_infer", "huber", nv["m"], nv["n"], nv["iters"], True)
    infer_spec("quickstart_infer", "sq", qs["m"], qs["n"], qs["iters"], False)

    for name, nonneg, (m, n) in [
        ("denoise_update", False, (dn["m"], dn["n"])),
        ("novelty_update", True, (nv["m"], nv["n"])),
    ]:
        specs[name] = dict(
            build=model.make_dict_update(nonneg=nonneg),
            args=[f32(n, m), f32(m), f32(n), f32()],
            meta=dict(
                kind="update",
                nonneg=nonneg,
                m=m,
                n=n,
                inputs=["wt", "nu", "y", "mu_w"],
                outputs=["wt_new"],
            ),
        )
    return specs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--scale", default="default", choices=["default", "tiny"])
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    specs = artifact_specs(args.scale)
    only = set(args.only.split(",")) if args.only else None

    manifest = {"version": 1, "scale": args.scale, "artifacts": {}}
    for name, spec in specs.items():
        if only and name not in only:
            continue
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        lowered = jax.jit(spec["build"]).lower(*spec["args"])
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = dict(file=fname, **spec["meta"])
        print(f"  wrote {path} ({len(text)//1024} KiB)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
