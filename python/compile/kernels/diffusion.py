"""L1 Pallas kernels: the fused per-iteration hot-spot of the diffusion
inference (paper Eqs. 31a/31b, Algs. 2-4).

State layout mirrors the rust engine: the dual iterates are stacked as
``V (N, M)`` (row k = agent k's nu) and the dictionary is stored
*transposed* as ``Wt (N, M)`` (row k = agent k's atom w_k; the paper's
experiments use one atom per agent, K = N). This makes the adapt step a
row-parallel fused elementwise+reduction (VPU-shaped) and the combine step
``V <- A^T Psi`` a plain matmul (MXU-shaped).

Kernels must run with ``interpret=True`` on CPU PJRT: real TPU lowering
emits Mosaic custom-calls the CPU plugin cannot execute. BlockSpecs are
still written for TPU tiling so the VMEM/MXU reasoning in DESIGN.md
carries over.

Scalar hyperparameters are packed into a ``params (8,)`` operand so one
AOT artifact serves every step-size/regularizer setting at a given shape:

    params = [mu, gamma, delta, cf_over_n, inv_informed, clip_bound,
              unused, unused]

``cf_over_n`` is c_f/N with grad f*(nu) = c_f nu (1 for squared-l2, eta
for Huber). ``clip_bound <= 0`` disables the V_f box projection.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Number of scalar slots in the params operand.
N_PARAMS = 8


def _threshold(s, gamma, *, onesided: bool):
    """T_gamma (two-sided) or T^+_gamma (one-sided) soft threshold."""
    if onesided:
        return jnp.maximum(s - gamma, 0.0)
    return jnp.sign(s) * jnp.maximum(jnp.abs(s) - gamma, 0.0)


def _adapt_kernel(v_ref, wt_ref, x_ref, theta_ref, params_ref, psi_ref, *, onesided: bool):
    """psi_k = nu_k - mu*(cf/N nu_k - theta_k x) - (mu/delta) thr(w_k^T nu_k) w_k.

    Operates on a (bn, M) row panel of V / Wt held in VMEM.
    """
    mu = params_ref[0]
    gamma = params_ref[1]
    delta = params_ref[2]
    cf_over_n = params_ref[3]

    v = v_ref[...]          # (bn, M)
    wt = wt_ref[...]        # (bn, M)
    x = x_ref[...]          # (M,)
    theta = theta_ref[...]  # (bn,)

    s = jnp.sum(wt * v, axis=1)                       # w_k^T nu_k, (bn,)
    thr = _threshold(s, gamma, onesided=onesided)     # (bn,)
    psi = (
        v * (1.0 - mu * cf_over_n)
        + mu * theta[:, None] * x[None, :]
        - (mu / delta) * thr[:, None] * wt
    )
    psi_ref[...] = psi


def adapt(v, wt, x, theta, params, *, onesided: bool, block_n: int = 64, interpret: bool = True):
    """Run the adapt step over all agents. Shapes: v,wt (N,M); x (M,);
    theta (N,); params (N_PARAMS,)."""
    n, m = v.shape
    bn = min(block_n, n)
    grid = (pl.cdiv(n, bn),)
    kernel = functools.partial(_adapt_kernel, onesided=onesided)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((N_PARAMS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), v.dtype),
        interpret=interpret,
    )(v, wt, x, theta, params)


def _combine_kernel(at_ref, psi_ref, params_ref, out_ref, *, clip: bool):
    """out = A^T Psi over a (bi, M) output panel; full-K contraction.

    The contraction dimension (neighbors) is loaded whole per program —
    at experiment scales (N <= 256) the (bi, N) x (N, M) panels fit VMEM
    comfortably; the matmul maps onto the MXU.
    """
    acc = jnp.dot(at_ref[...], psi_ref[...], preferred_element_type=jnp.float32)
    if clip:
        bound = params_ref[5]
        acc = jnp.clip(acc, -bound, bound)
    out_ref[...] = acc.astype(out_ref.dtype)


def combine(at, psi, params, *, clip: bool, block_n: int = 64, interpret: bool = True):
    """Combine step ``V = A^T Psi`` (+ optional entrywise clip to
    [-params[5], params[5]], Eq. 35b). at is A transposed, (N, N)."""
    n, m = psi.shape
    bn = min(block_n, n)
    grid = (pl.cdiv(n, bn),)
    kernel = functools.partial(_combine_kernel, clip=clip)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, n), lambda i: (i, 0)),
            pl.BlockSpec((n, m), lambda i: (0, 0)),
            pl.BlockSpec((N_PARAMS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), psi.dtype),
        interpret=interpret,
    )(at, psi, params)


def diffusion_step(v, wt, x, at, theta, params, *, onesided: bool, clip: bool,
                   block_n: int = 64, interpret: bool = True):
    """One full ATC diffusion iteration: adapt then combine."""
    psi = adapt(v, wt, x, theta, params, onesided=onesided, block_n=block_n,
                interpret=interpret)
    return combine(at, psi, params, clip=clip, block_n=block_n, interpret=interpret)


def _recover_kernel(v_ref, wt_ref, params_ref, y_ref, *, onesided: bool):
    """y_k = thr_gamma(w_k^T nu_k)/delta (Eq. 37 / Table II)."""
    gamma = params_ref[1]
    delta = params_ref[2]
    s = jnp.sum(wt_ref[...] * v_ref[...], axis=1)
    y_ref[...] = _threshold(s, gamma, onesided=onesided) / delta


def recover_y(v, wt, params, *, onesided: bool, block_n: int = 64, interpret: bool = True):
    """Primal recovery for every agent's own atom from its own dual row."""
    n, m = v.shape
    bn = min(block_n, n)
    grid = (pl.cdiv(n, bn),)
    kernel = functools.partial(_recover_kernel, onesided=onesided)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((N_PARAMS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), v.dtype),
        interpret=interpret,
    )(v, wt, params)


def pack_params(mu, gamma, delta, cf_over_n, inv_informed=0.0, clip_bound=0.0):
    """Pack scalars into the params operand."""
    return jnp.array(
        [mu, gamma, delta, cf_over_n, inv_informed, clip_bound, 0.0, 0.0],
        dtype=jnp.float32,
    )
