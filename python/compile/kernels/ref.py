"""Pure-jnp oracle for the Pallas kernels (the L1 correctness contract).

Implements the same diffusion step with plain jax.numpy; pytest asserts
allclose between this and the Pallas path across shapes/dtypes/params
(hypothesis sweeps in python/tests/test_kernels.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def threshold(s, gamma, *, onesided: bool):
    """T_gamma / T^+_gamma soft threshold (paper Eqs. 78/86)."""
    if onesided:
        return jnp.maximum(s - gamma, 0.0)
    return jnp.sign(s) * jnp.maximum(jnp.abs(s) - gamma, 0.0)


def adapt(v, wt, x, theta, params, *, onesided: bool):
    """Reference adapt step (Eq. 31a specialization, Algs. 2-4)."""
    mu, gamma, delta, cf_over_n = params[0], params[1], params[2], params[3]
    s = jnp.sum(wt * v, axis=1)
    thr = threshold(s, gamma, onesided=onesided)
    return (
        v * (1.0 - mu * cf_over_n)
        + mu * theta[:, None] * x[None, :]
        - (mu / delta) * thr[:, None] * wt
    )


def combine(at, psi, params, *, clip: bool):
    """Reference combine step V = A^T Psi (Eq. 31b), optional box (35b)."""
    out = at @ psi
    if clip:
        bound = params[5]
        out = jnp.clip(out, -bound, bound)
    return out


def diffusion_step(v, wt, x, at, theta, params, *, onesided: bool, clip: bool):
    """One full ATC iteration."""
    return combine(at, adapt(v, wt, x, theta, params, onesided=onesided), params, clip=clip)


def recover_y(v, wt, params, *, onesided: bool):
    """y_k = thr_gamma(w_k^T nu_k)/delta (Eq. 37 / Table II)."""
    gamma, delta = params[1], params[2]
    s = jnp.sum(wt * v, axis=1)
    return threshold(s, gamma, onesided=onesided) / delta


def run_inference(wt, x, at, theta, params, iters, *, onesided: bool, clip: bool):
    """Full reference inference loop (python loop; small iters only)."""
    n, m = wt.shape
    v = jnp.zeros((n, m), dtype=wt.dtype)
    for _ in range(iters):
        v = diffusion_step(v, wt, x, at, theta, params, onesided=onesided, clip=clip)
    return v, recover_y(v, wt, params, onesided=onesided)
