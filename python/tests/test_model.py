"""L2 correctness: fused inference graphs, dictionary update, novelty cost."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import diffusion as K
from compile.kernels import ref as R


def problem(n, m, seed=0):
    rng = np.random.default_rng(seed)
    wt = rng.standard_normal((n, m)).astype(np.float32)
    wt /= np.maximum(np.linalg.norm(wt, axis=1, keepdims=True), 1e-6)
    x = rng.standard_normal(m).astype(np.float32)
    # Metropolis-like symmetric doubly-stochastic matrix: lazy random walk.
    a = np.full((n, n), 1.0 / (2 * n), dtype=np.float32)
    np.fill_diagonal(a, 1.0 / (2 * n) + 0.5)
    theta = np.full(n, 1.0 / n, dtype=np.float32)
    return jnp.array(wt), jnp.array(x), jnp.array(a), jnp.array(theta)


@pytest.mark.parametrize("variant", ["sq", "nmf", "huber"])
def test_fused_inference_matches_ref_loop(variant):
    n, m, iters = 7, 11, 40
    wt, x, at, theta = problem(n, m, seed=3)
    params = K.pack_params(0.2, 0.3, 0.4, 1.0 / n, clip_bound=1.0)
    flags = model._variant_flags(variant)
    infer = model.make_inference(variant, iters, use_pallas=True, block_n=4)
    v_got, y_got = jax.jit(infer)(wt, x, at, theta, params)
    v_want, y_want = R.run_inference(wt, x, at, theta, params, iters, **flags)
    np.testing.assert_allclose(v_got, v_want, rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(y_got, y_want, rtol=5e-5, atol=5e-5)


def test_pallas_and_jnp_paths_agree():
    n, m, iters = 6, 9, 25
    wt, x, at, theta = problem(n, m, seed=4)
    params = K.pack_params(0.3, 0.2, 0.5, 1.0 / n)
    f_pallas = model.make_inference("sq", iters, use_pallas=True, block_n=8)
    f_jnp = model.make_inference("sq", iters, use_pallas=False)
    v1, y1 = jax.jit(f_pallas)(wt, x, at, theta, params)
    v2, y2 = jax.jit(f_jnp)(wt, x, at, theta, params)
    np.testing.assert_allclose(v1, v2, rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(y1, y2, rtol=5e-5, atol=5e-5)


def test_dict_update_projection():
    n, m = 5, 8
    rng = np.random.default_rng(5)
    wt = jnp.array(rng.standard_normal((n, m)).astype(np.float32))
    nu = jnp.array(rng.standard_normal(m).astype(np.float32)) * 10.0
    y = jnp.array(rng.standard_normal(n).astype(np.float32))
    out = model.dict_update(wt, nu, y, 1.0, nonneg=False)
    norms = np.linalg.norm(np.asarray(out), axis=1)
    assert (norms <= 1.0 + 1e-5).all()
    out_nn = model.dict_update(wt, nu, y, 1.0, nonneg=True)
    assert np.asarray(out_nn).min() >= 0.0


def test_dict_update_zero_step_inside_ball_is_identity():
    n, m = 4, 6
    rng = np.random.default_rng(6)
    wt = rng.standard_normal((n, m)).astype(np.float32)
    wt /= 2.0 * np.linalg.norm(wt, axis=1, keepdims=True)  # strictly inside
    out = model.dict_update(jnp.array(wt), jnp.zeros(m), jnp.zeros(n), 0.0, nonneg=False)
    np.testing.assert_allclose(out, wt, rtol=1e-6, atol=1e-7)


def test_novelty_cost_orders_fit_quality():
    """A document synthesized from the atoms must score lower than an
    orthogonal one (the detector's core property)."""
    n, m, iters = 8, 20, 400
    rng = np.random.default_rng(7)
    wt = np.abs(rng.standard_normal((n, m))).astype(np.float32)
    wt /= np.linalg.norm(wt, axis=1, keepdims=True)
    a = np.full((n, n), 1.0 / n, dtype=np.float32)
    theta = np.full(n, 1.0 / n, dtype=np.float32)
    params = K.pack_params(0.3, 0.05, 0.1, 1.0 / n)

    modeled = wt.T @ np.abs(rng.random(n)).astype(np.float32)
    modeled /= np.linalg.norm(modeled)
    novel = np.abs(rng.standard_normal(m)).astype(np.float32)
    novel /= np.linalg.norm(novel)

    run = jax.jit(model.make_infer_with_cost("nmf", iters, use_pallas=False))
    def score(x):
        _, _, c = run(jnp.array(wt), jnp.array(x), jnp.array(a), jnp.array(theta), params)
        return float(c)

    assert score(novel) > score(modeled)


def test_novelty_cost_matches_primal_at_optimum():
    """Strong duality: the converged score equals the primal objective."""
    n, m, iters = 6, 10, 3000
    wt, x, at, theta = problem(n, m, seed=8)
    at = jnp.full((n, n), 1.0 / n)  # fully connected for fast consensus
    gamma, delta = 0.1, 0.5
    params = K.pack_params(0.3, gamma, delta, 1.0 / n)
    run = jax.jit(model.make_infer_with_cost("sq", iters, use_pallas=False))
    v, y, cost = run(wt, x, at, theta, params)
    resid = np.asarray(x) - np.asarray(wt).T @ np.asarray(y)
    primal = (0.5 * (resid ** 2).sum()
              + gamma * np.abs(np.asarray(y)).sum()
              + 0.5 * delta * (np.asarray(y) ** 2).sum())
    assert abs(float(cost) - primal) < 2e-2 * (1.0 + primal), (float(cost), primal)
