"""AOT path: artifacts lower to valid HLO text and the manifest is sane.

Uses the `tiny` scale preset so lowering stays fast in CI.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PY_DIR = os.path.join(REPO, "python")


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--scale", "tiny",
         "--only", "quickstart_infer,novelty_huber_infer,denoise_update"],
        cwd=PY_DIR,
        check=True,
    )
    return out


def test_manifest_schema(tiny_artifacts):
    with open(tiny_artifacts / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    assert "quickstart_infer" in arts
    qi = arts["quickstart_infer"]
    assert qi["kind"] == "infer"
    assert qi["inputs"] == ["wt", "x", "at", "theta", "params"]
    assert qi["m"] == 16 and qi["n"] == 8
    up = arts["denoise_update"]
    assert up["kind"] == "update"
    assert up["outputs"] == ["wt_new"]


def test_hlo_text_is_parseable_hlo(tiny_artifacts):
    text = (tiny_artifacts / "quickstart_infer.hlo.txt").read_text()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # The fused loop must appear as a while op, not an unrolled body.
    assert "while" in text
    # No Mosaic custom-calls (interpret=True guarantees plain HLO ops).
    assert "tpu_custom_call" not in text


def test_huber_artifact_contains_box_projection(tiny_artifacts):
    text = (tiny_artifacts / "novelty_huber_infer.hlo.txt").read_text()
    # jnp.clip lowers to clamp or a maximum/minimum pair depending on version.
    assert "clamp" in text or ("maximum" in text and "minimum" in text), (
        "l-inf projection should lower to clamp or min/max"
    )
