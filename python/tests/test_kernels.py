"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and hyperparameters; every property asserts
allclose between the interpret-mode Pallas path and ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import diffusion as K
from compile.kernels import ref as R

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand_problem(rng, n, m):
    v = rng.standard_normal((n, m)).astype(np.float32)
    wt = rng.standard_normal((n, m)).astype(np.float32)
    wt /= np.maximum(np.linalg.norm(wt, axis=1, keepdims=True), 1e-6)
    x = rng.standard_normal(m).astype(np.float32)
    at = rng.random((n, n)).astype(np.float32)
    at /= at.sum(axis=1, keepdims=True)  # row-stochastic is enough for math checks
    theta = np.full(n, 1.0 / n, dtype=np.float32)
    return v, wt, x, at, theta


shape_st = st.tuples(st.integers(2, 40), st.integers(2, 50))
param_st = st.tuples(
    st.floats(0.01, 1.0),   # mu
    st.floats(0.0, 2.0),    # gamma
    st.floats(0.05, 1.0),   # delta
    st.floats(0.1, 1.0),    # cf (as c_f, divided by n below)
)


@given(shape=shape_st, hp=param_st, onesided=st.booleans(), seed=st.integers(0, 2**31))
def test_adapt_matches_ref(shape, hp, onesided, seed):
    n, m = shape
    mu, gamma, delta, cf = hp
    rng = np.random.default_rng(seed)
    v, wt, x, _, theta = rand_problem(rng, n, m)
    params = K.pack_params(mu, gamma, delta, cf / n)
    got = K.adapt(jnp.array(v), jnp.array(wt), jnp.array(x), jnp.array(theta),
                  params, onesided=onesided, block_n=16)
    want = R.adapt(jnp.array(v), jnp.array(wt), jnp.array(x), jnp.array(theta),
                   params, onesided=onesided)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(shape=shape_st, clip=st.booleans(), seed=st.integers(0, 2**31))
def test_combine_matches_ref(shape, clip, seed):
    n, m = shape
    rng = np.random.default_rng(seed)
    v, _, _, at, _ = rand_problem(rng, n, m)
    params = K.pack_params(0.1, 0.5, 0.2, 1.0 / n, clip_bound=0.7)
    got = K.combine(jnp.array(at), jnp.array(v), params, clip=clip, block_n=16)
    want = R.combine(jnp.array(at), jnp.array(v), params, clip=clip)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    if clip:
        assert np.abs(np.asarray(got)).max() <= 0.7 + 1e-6


@given(shape=shape_st, hp=param_st, onesided=st.booleans(), clip=st.booleans(),
       seed=st.integers(0, 2**31))
def test_full_step_matches_ref(shape, hp, onesided, clip, seed):
    n, m = shape
    mu, gamma, delta, cf = hp
    rng = np.random.default_rng(seed)
    v, wt, x, at, theta = rand_problem(rng, n, m)
    params = K.pack_params(mu, gamma, delta, cf / n, clip_bound=1.0)
    got = K.diffusion_step(jnp.array(v), jnp.array(wt), jnp.array(x), jnp.array(at),
                           jnp.array(theta), params, onesided=onesided, clip=clip,
                           block_n=16)
    want = R.diffusion_step(jnp.array(v), jnp.array(wt), jnp.array(x), jnp.array(at),
                            jnp.array(theta), params, onesided=onesided, clip=clip)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@given(shape=shape_st, hp=param_st, onesided=st.booleans(), seed=st.integers(0, 2**31))
def test_recover_y_matches_ref(shape, hp, onesided, seed):
    n, m = shape
    mu, gamma, delta, cf = hp
    rng = np.random.default_rng(seed)
    v, wt, _, _, _ = rand_problem(rng, n, m)
    params = K.pack_params(mu, gamma, delta, cf / n)
    got = K.recover_y(jnp.array(v), jnp.array(wt), params, onesided=onesided, block_n=16)
    want = R.recover_y(jnp.array(v), jnp.array(wt), params, onesided=onesided)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    if onesided:
        assert np.asarray(got).min() >= 0.0


def test_block_size_invariance():
    """Tiling must not change results (BlockSpec correctness)."""
    rng = np.random.default_rng(0)
    v, wt, x, at, theta = rand_problem(rng, 37, 23)  # awkward sizes
    params = K.pack_params(0.3, 0.4, 0.2, 1.0 / 37)
    outs = [
        np.asarray(K.diffusion_step(jnp.array(v), jnp.array(wt), jnp.array(x),
                                    jnp.array(at), jnp.array(theta), params,
                                    onesided=False, clip=False, block_n=b))
        for b in (4, 16, 37, 64)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)


def test_threshold_zero_gamma_is_identity_two_sided():
    s = jnp.array([-2.0, -0.5, 0.0, 0.7, 3.0])
    np.testing.assert_allclose(R.threshold(s, 0.0, onesided=False), s)
    np.testing.assert_allclose(
        R.threshold(s, 0.0, onesided=True), jnp.maximum(s, 0.0)
    )


@pytest.mark.parametrize("onesided", [False, True])
def test_inference_loop_reaches_consensus(onesided):
    """With a doubly-stochastic A and small mu, agents agree at the end."""
    rng = np.random.default_rng(1)
    n, m = 8, 12
    v, wt, x, _, theta = rand_problem(rng, n, m)
    at = np.full((n, n), 1.0 / n, dtype=np.float32)  # fully connected
    params = K.pack_params(0.2, 0.1, 0.5, 1.0 / n)
    v, y = R.run_inference(jnp.array(wt), jnp.array(x), jnp.array(at),
                           jnp.array(theta), params, 300,
                           onesided=onesided, clip=False)
    v = np.asarray(v)
    spread = np.abs(v - v.mean(axis=0, keepdims=True)).max()
    assert spread < 1e-4, spread
